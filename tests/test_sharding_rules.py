"""Unit tests for the sharding rules (no multi-device needed: rules are pure
functions of paths/shapes/mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding
from repro.launch.step import abstract_serve_params, abstract_train_state, make_optimizer


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An abstract mesh for rule evaluation (no devices needed)."""
    return sharding.abstract_mesh(shape, axes)


def _spec_of(tree_sh, *path):
    node = tree_sh
    for k in path:
        node = node[k]
    return node.spec


def test_param_specs_llama_train():
    cfg = get_config("llama3.2-3b")
    mesh = fake_mesh()
    params, _ = abstract_train_state(cfg, make_optimizer(cfg))
    sh = sharding.param_shardings(mesh, params)
    # embed: vocab over model only
    assert _spec_of(sh, "embed", "w") == P("model", None)
    # column-parallel qkv: (in~data, out~model)
    assert _spec_of(sh, "first", "mixer", "qkv", "w") == P("data", "model")
    # row-parallel attn out: (in~model, out~data)
    assert _spec_of(sh, "first", "mixer", "out", "w") == P("model", "data")
    # scanned stack: leading period dim unsharded
    assert _spec_of(sh, "mid", "b0", "ffn", "up", "w") == P(None, "data", "model")
    assert _spec_of(sh, "mid", "b0", "ffn", "down", "w") == P(None, "model", "data")
    # norms replicated
    assert _spec_of(sh, "final_norm", "scale") == P(None)


def test_param_specs_moe_experts():
    cfg = get_config("deepseek-moe-16b")
    mesh = fake_mesh()
    params, _ = abstract_train_state(cfg, make_optimizer(cfg))
    sh = sharding.param_shardings(mesh, params)
    # experts: EP over model; deepseek E=64 /16 = 4 per device
    spec = _spec_of(sh, "mid", "b0", "ffn", "up", "w")
    assert spec[1] == "model"      # (lead, E, in, out)
    # router replicated on expert dim
    rspec = _spec_of(sh, "mid", "b0", "ffn", "router", "w")
    assert rspec[-1] is None


def test_serve_packed_specs():
    cfg = get_config("llama3.2-3b")
    mesh = fake_mesh()
    params = abstract_serve_params(cfg)
    sh = sharding.param_shardings(mesh, params, fsdp=False)
    # first layer is int8 weight-only (first/last override of w-ternary)
    spec = _spec_of(sh, "first", "mixer", "qkv", "w_q")
    assert spec == P(None, "model")
    # body: ternary planes (out, K/32) column-parallel -> out over model
    spec = _spec_of(sh, "mid", "b0", "mixer", "qkv", "w_mask")
    assert spec == P(None, "model", None)
    # row-parallel packed down proj: K-words over model
    spec = _spec_of(sh, "mid", "b0", "ffn", "down", "w_mask")
    assert spec == P(None, None, "model")


def test_packed_k_rules_guard_non_dividing_pack_factor():
    """Serve packed-weight rules shard the *packed* last axis of
    w_packed/w_mask/w_sign (K/32-bit words). A shard boundary must never
    fall inside a packed word: K must divide pack_factor(32) x shard_count.
    K=96 -> 3 words does NOT split over a 2-way model axis — the rule must
    fall back to replicated, not shard mid-word; K=128 -> 4 words does."""
    from repro.core import pack
    from repro.core.precision import LayerQuant
    from repro.core.quantize import QuantSpec
    from repro.core.qlinear import QLinearSpec, init as qinit, pack_params

    # the shared predicate itself (kernels.dispatch.tp_plan uses the same one)
    assert pack.shardable_words(4, 2)
    assert not pack.shardable_words(3, 2)       # 96 ops / (32 * 2): mid-word
    assert not pack.shardable_words(4, 0)

    mesh = fake_mesh((2, 2))
    lq = LayerQuant(QuantSpec("ternary"), QuantSpec("ternary"))

    def packed_down(k):
        spec = QLinearSpec(k, 64, lq)
        return {"ffn": {"down": pack_params(
            qinit(jax.random.PRNGKey(0), spec), spec)}}

    ok = sharding.param_shardings(mesh, packed_down(128), fsdp=False)
    bad = sharding.param_shardings(mesh, packed_down(96), fsdp=False)
    # dividing packed K: row-parallel words over "model"
    assert ok["ffn"]["down"]["w_mask"].spec == P(None, "model")
    assert ok["ffn"]["down"]["w_sign"].spec == P(None, "model")
    # non-dividing packed K: replicated fallback on the packed axis
    assert bad["ffn"]["down"]["w_mask"].spec == P(None, None)
    assert bad["ffn"]["down"]["w_sign"].spec == P(None, None)
    # and the kernels-side arbiter agrees (layout and compute can't diverge)
    from repro.kernels import dispatch
    cell = dispatch.lookup("ternary", "ternary", "popcount")
    tp = dispatch.TPSpec(sharding.abstract_mesh((2, 2)))
    assert dispatch.tp_plan(cell, QLinearSpec(128, 64, lq, parallel="row"),
                            "row", tp) == "row"
    assert dispatch.tp_plan(cell, QLinearSpec(96, 64, lq, parallel="row"),
                            "row", tp) is None


def test_packed_k_rules_int4_pack_factor():
    """The s4 nibble format packs 8 operands per word (pack.K_QUANTUM=8):
    K=48 -> 6 words splits 2-way but K=40 -> 5 words does not — both the
    device-layout rule (w_q4 is in the packed set) and dispatch.tp_plan
    (cell.k_quantum) must agree on the fallback."""
    from repro.core import pack
    from repro.core.precision import LayerQuant
    from repro.core.quantize import QuantSpec
    from repro.core.qlinear import QLinearSpec, init as qinit, pack_params
    from repro.kernels import dispatch

    assert pack.K_QUANTUM["w_q4"] == 8
    mesh = fake_mesh((2, 2))
    lq = LayerQuant(QuantSpec("int4"), QuantSpec("int8"))

    def packed_down(k):
        spec = QLinearSpec(k, 64, lq)
        return {"ffn": {"down": pack_params(
            qinit(jax.random.PRNGKey(0), spec), spec)}}

    ok = sharding.param_shardings(mesh, packed_down(48), fsdp=False)
    bad = sharding.param_shardings(mesh, packed_down(40), fsdp=False)
    assert ok["ffn"]["down"]["w_q4"].spec == P(None, "model")
    assert bad["ffn"]["down"]["w_q4"].spec == P(None, None)

    cell = dispatch.lookup("int4", "int8")
    assert cell.k_quantum == 8
    tp = dispatch.TPSpec(sharding.abstract_mesh((2, 2)))
    assert dispatch.tp_plan(cell, QLinearSpec(48, 64, lq, parallel="row"),
                            "row", tp) == "row"
    assert dispatch.tp_plan(cell, QLinearSpec(40, 64, lq, parallel="row"),
                            "row", tp) is None


def test_serve_cache_shardings_pool_over_data():
    """Paged pool leaves shard the page axis over "data" (whole pages per
    shard); slab leaves shard the slot axis; non-dividing pools replicate."""
    from repro.models import transformer
    from repro.launch import kv_cache

    cfg = get_config("gemma3-4b").reduced()    # windowed: pool + ring slabs
    mesh = fake_mesh((2, 2))
    slots, cache_len, num_pages, page_size = 4, 64, 16, 8
    shapes = transformer.cache_shapes(cfg, slots, cache_len,
                                      paged=(num_pages, page_size))
    mask = kv_cache.paged_leaf_mask(cfg, slots, cache_len, num_pages, page_size)
    sh = sharding.serve_cache_shardings(mesh, shapes)
    flat_sh, flat_mask = jax.tree.leaves(sh), jax.tree.leaves(mask)
    assert any(flat_mask) and not all(flat_mask)
    for s, is_paged in zip(flat_sh, flat_mask):
        lead = s.spec[0] if len(s.spec) else None
        assert lead in ("data", None)
    # the pool (page axis 16 % 2 == 0) really shards; an odd pool doesn't
    paged_leaf = [s for s, m_ in zip(flat_sh, flat_mask) if m_][0]
    assert paged_leaf.spec[0] == "data"
    odd = transformer.cache_shapes(cfg, slots, cache_len, paged=(17, page_size))
    mask_odd = kv_cache.paged_leaf_mask(cfg, slots, cache_len, 17, page_size)
    sh_odd = sharding.serve_cache_shardings(mesh, odd)
    odd_leaf = [s for s, m_ in zip(jax.tree.leaves(sh_odd),
                                   jax.tree.leaves(mask_odd)) if m_][0]
    assert odd_leaf.spec[0] is None


def test_fit_spec_drops_nondividing():
    mesh = fake_mesh()
    assert sharding.fit_spec(P("model", None), (51865, 384), mesh) == P(None, None)
    assert sharding.fit_spec(P("model", None), (51872, 384), mesh) == P("model", None)
    assert sharding.fit_spec(P(("data", "model")), (512,), mesh) == P(("data", "model"))
    assert sharding.fit_spec(P(("data", "model")), (100,), mesh) == P(None)


def test_opt_state_shards_like_params():
    cfg = get_config("xlstm-125m")
    mesh = fake_mesh()
    opt = make_optimizer(cfg)
    params, opt_state = abstract_train_state(cfg, opt)
    ps = sharding.param_shardings(mesh, params)
    os_ = sharding.opt_state_shardings(mesh, opt_state, ps)
    flat_p = jax.tree.leaves(ps)
    flat_m = jax.tree.leaves(os_.m)
    assert len(flat_p) == len(flat_m)
    for a, b in zip(flat_p, flat_m):
        assert a.spec == b.spec


def test_cache_specs():
    cfg = get_config("recurrentgemma-9b")
    from repro.models import transformer
    mesh = fake_mesh()
    shapes = transformer.cache_shapes(cfg, 128, 32768)
    sh = sharding.cache_shardings(mesh, shapes, batch=128)
    # attention kv: batch over data, seq over model
    kspec = sh["mid"]["b1"]["k"].spec  # pattern offset 1: b1 is the "local" layer
    assert kspec == P(None, "data", "model", None, None)  # (lead, B, S, Hk, dh)
    # rglru state h (B, Dr): batch + model
    hspec = sh["mid"]["b0"]["h"].spec
    assert hspec[1] == "data"
    # batch=1: nothing sharded on batch
    sh1 = sharding.cache_shardings(mesh, transformer.cache_shapes(cfg, 1, 1024),
                                   batch=1)
    assert sh1["first"]["h"].spec[0] is None
