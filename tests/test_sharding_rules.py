"""Unit tests for the sharding rules (no multi-device needed: rules are pure
functions of paths/shapes/mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding
from repro.launch.step import abstract_serve_params, abstract_train_state, make_optimizer


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An abstract mesh for rule evaluation (no devices needed)."""
    return sharding.abstract_mesh(shape, axes)


def _spec_of(tree_sh, *path):
    node = tree_sh
    for k in path:
        node = node[k]
    return node.spec


def test_param_specs_llama_train():
    cfg = get_config("llama3.2-3b")
    mesh = fake_mesh()
    params, _ = abstract_train_state(cfg, make_optimizer(cfg))
    sh = sharding.param_shardings(mesh, params)
    # embed: vocab over model only
    assert _spec_of(sh, "embed", "w") == P("model", None)
    # column-parallel qkv: (in~data, out~model)
    assert _spec_of(sh, "first", "mixer", "qkv", "w") == P("data", "model")
    # row-parallel attn out: (in~model, out~data)
    assert _spec_of(sh, "first", "mixer", "out", "w") == P("model", "data")
    # scanned stack: leading period dim unsharded
    assert _spec_of(sh, "mid", "b0", "ffn", "up", "w") == P(None, "data", "model")
    assert _spec_of(sh, "mid", "b0", "ffn", "down", "w") == P(None, "model", "data")
    # norms replicated
    assert _spec_of(sh, "final_norm", "scale") == P(None)


def test_param_specs_moe_experts():
    cfg = get_config("deepseek-moe-16b")
    mesh = fake_mesh()
    params, _ = abstract_train_state(cfg, make_optimizer(cfg))
    sh = sharding.param_shardings(mesh, params)
    # experts: EP over model; deepseek E=64 /16 = 4 per device
    spec = _spec_of(sh, "mid", "b0", "ffn", "up", "w")
    assert spec[1] == "model"      # (lead, E, in, out)
    # router replicated on expert dim
    rspec = _spec_of(sh, "mid", "b0", "ffn", "router", "w")
    assert rspec[-1] is None


def test_serve_packed_specs():
    cfg = get_config("llama3.2-3b")
    mesh = fake_mesh()
    params = abstract_serve_params(cfg)
    sh = sharding.param_shardings(mesh, params, fsdp=False)
    # first layer is int8 weight-only (first/last override of w-ternary)
    spec = _spec_of(sh, "first", "mixer", "qkv", "w_q")
    assert spec == P(None, "model")
    # body: ternary planes (out, K/32) column-parallel -> out over model
    spec = _spec_of(sh, "mid", "b0", "mixer", "qkv", "w_mask")
    assert spec == P(None, "model", None)
    # row-parallel packed down proj: K-words over model
    spec = _spec_of(sh, "mid", "b0", "ffn", "down", "w_mask")
    assert spec == P(None, None, "model")


def test_fit_spec_drops_nondividing():
    mesh = fake_mesh()
    assert sharding.fit_spec(P("model", None), (51865, 384), mesh) == P(None, None)
    assert sharding.fit_spec(P("model", None), (51872, 384), mesh) == P("model", None)
    assert sharding.fit_spec(P(("data", "model")), (512,), mesh) == P(("data", "model"))
    assert sharding.fit_spec(P(("data", "model")), (100,), mesh) == P(None)


def test_opt_state_shards_like_params():
    cfg = get_config("xlstm-125m")
    mesh = fake_mesh()
    opt = make_optimizer(cfg)
    params, opt_state = abstract_train_state(cfg, opt)
    ps = sharding.param_shardings(mesh, params)
    os_ = sharding.opt_state_shardings(mesh, opt_state, ps)
    flat_p = jax.tree.leaves(ps)
    flat_m = jax.tree.leaves(os_.m)
    assert len(flat_p) == len(flat_m)
    for a, b in zip(flat_p, flat_m):
        assert a.spec == b.spec


def test_cache_specs():
    cfg = get_config("recurrentgemma-9b")
    from repro.models import transformer
    mesh = fake_mesh()
    shapes = transformer.cache_shapes(cfg, 128, 32768)
    sh = sharding.cache_shardings(mesh, shapes, batch=128)
    # attention kv: batch over data, seq over model
    kspec = sh["mid"]["b1"]["k"].spec  # pattern offset 1: b1 is the "local" layer
    assert kspec == P(None, "data", "model", None, None)  # (lead, B, S, Hk, dh)
    # rglru state h (B, Dr): batch + model
    hspec = sh["mid"]["b0"]["h"].spec
    assert hspec[1] == "data"
    # batch=1: nothing sharded on batch
    sh1 = sharding.cache_shardings(mesh, transformer.cache_shapes(cfg, 1, 1024),
                                   batch=1)
    assert sh1["first"]["h"].spec[0] is None
