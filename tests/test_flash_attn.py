"""Flash-attention Pallas kernel vs blockless oracle: shape/GQA/causal sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention
from repro.kernels import ref


def _oracle(q, k, v, causal):
    bh, tq, dh = q.shape
    bhk, tk, _ = k.shape
    g = bh // bhk
    kk = jnp.repeat(k, g, axis=0)
    vv = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("htd,hsd->hts", q, kk).astype(jnp.float32) / dh ** 0.5
    if causal:
        mask = jnp.arange(tq)[:, None] >= jnp.arange(tk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hts,hsd->htd", a.astype(q.dtype), vv)


@pytest.mark.parametrize("bh,bhk,tq,tk,dh", [
    (4, 4, 128, 128, 64),     # MHA
    (6, 2, 128, 128, 64),     # GQA g=3
    (4, 1, 256, 256, 32),     # MQA
    (2, 2, 256, 512, 128),    # cross-ish (tq != tk)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(bh, bhk, tq, tk, dh, causal):
    if causal and tq != tk:
        pytest.skip("causal requires tq == tk in this sweep")
    ks = jax.random.split(jax.random.PRNGKey(bh * tq + dh), 3)
    q = jax.random.normal(ks[0], (bh, tq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (bhk, tk, dh), jnp.float32)
    v = jax.random.normal(ks[2], (bhk, tk, dh), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    want = _oracle(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 256, 64))
    k = jax.random.normal(ks[1], (2, 256, 64))
    v = jax.random.normal(ks[2], (2, 256, 64))
    a = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    b = flash_attention(q, k, v, causal=True, bq=128, bk=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (2, 128, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 128, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 128, 64)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True)
    want = _oracle(q, k, v, True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)
