"""Expert-parallel MoE serving lockdown: the grouped expert dispatch on a
("data", "model") mesh must be TOKEN-EXACT against the single-device
dense-vmap server, drops included.

Why exactness is achievable (and therefore demanded): routing is replicated
and deterministic (jax.lax.top_k breaks ties to the lowest expert index,
capacity slots come from a cumsum — no RNG, no device-count dependence), so
every shard agrees on which token goes to which expert slot and which
assignments drop. The up projection computes local experts with no
collective; the down projection zero-embeds each shard's local accumulators
into the full (E, M, N) and psums — a DISJOINT assembly (one real producer
per element, x + 0 == x), exact at any accumulator width, which is what lets
the narrow weight-only deepseek policy ("ternary"/"none" cells) EP-shard
where TP-row must fall back. Any relaxation — a float reduction over a
shared element, per-shard routing, capacity depending on shard count —
shows up here as a token mismatch, not a tolerance warning.

Runs in a subprocess with --xla_force_host_platform_device_count=8 (same
pattern as test_serving_tp.py) so the device-count flag can't leak into the
rest of the suite.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> subprocess.CompletedProcess:
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    return subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, env=env, cwd=REPO, timeout=900)


SCRIPT_QGEMM = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8
from repro.core import qlinear
from repro.core.precision import LayerQuant
from repro.core.quantize import QuantSpec
from repro.kernels import dispatch
from repro.kernels.dispatch import OperatingPoint

MESH = jax.make_mesh((2, 4), ("data", "model"))

def build(wprec, aprec, bias, experts, k, parallel, seed=0):
    spec = qlinear.QLinearSpec(
        k, 32, LayerQuant(QuantSpec(wprec), QuantSpec(aprec)),
        use_bias=bias, experts=experts, parallel=parallel)
    p = qlinear.init(jax.random.PRNGKey(seed), spec)
    if bias:
        p["b"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                   p["b"].shape) * 0.1
    return spec, qlinear.pack_params(p, spec)

def check(cellkey, parallel, experts, backend, bias):
    wprec, aprec, impl = cellkey
    impl_arg = "popcount" if impl == "*" else impl
    spec, p = build(wprec, aprec, bias, experts, 64, parallel)
    op = OperatingPoint.for_spec(spec, impl=impl_arg, backend=backend)
    x = jax.random.normal(jax.random.PRNGKey(experts), (experts, 5, 64)) * 0.2
    ref = dispatch.qgemm(p, x, spec, op)                       # dense-vmap oracle
    ep = dispatch.EPSpec(MESH)
    plan = dispatch.ep_plan(dispatch.lookup(op), spec, parallel, ep)
    y = dispatch.qgemm(p, x, spec, op, ep=ep, parallel=parallel)
    assert y.shape == ref.shape and y.dtype == ref.dtype
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(ref, np.float32),
        err_msg=str((cellkey, parallel, experts, backend, bias, plan)))
    return plan

planned = 0
for cellkey in sorted(dispatch.cells()):
    for parallel in ("column", "row"):
        if check(cellkey, parallel, 4, "jnp", True):
            planned += 1
        # E=6 does not divide model=4: ep_plan must decline, dense fallback
        assert check(cellkey, parallel, 6, "jnp", False) is None
assert planned >= 2 * len(dispatch.cells()) // 2, planned
# pallas backend: the grouped harness launch, wide W&A + mixed-precision cells
for cellkey in (("ternary", "int8", "*"), ("int8", "int8", "*")):
    for parallel in ("column", "row"):
        assert check(cellkey, parallel, 4, "pallas", True) == parallel
# narrow weight-only cell EP-shards in row mode (disjoint assembly is exact
# at bf16) where tp_plan would refuse
spec, _ = build("ternary", "none", False, 4, 64, "row")
cell = dispatch.lookup(OperatingPoint.for_spec(spec))
assert not cell.wide
assert dispatch.ep_plan(cell, spec, "row", dispatch.EPSpec(MESH)) == "row"
print("EP_QGEMM_OK", planned)
'''


SCRIPT_SERVE = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8
from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx

mesh = jax.make_mesh((2, 4), ("data", "model"))
PROMPT_LENS, MAX_NEW, CACHE_LEN, PAGE_SIZE = (3, 9, 14), 4, 32, 4
NUM_PAGES = 24
rng = np.random.default_rng(7)

def serve(cfg, sparams, ctx, prompts, mesh_, moe_ep=True):
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=NUM_PAGES, ctx=ctx,
                 mesh=mesh_, moe_ep=moe_ep)
    assert (srv.ctx.ep is not None) == (mesh_ is not None and moe_ep)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, MAX_NEW))
    srv.run()
    assert len(srv.completed) == len(prompts)
    assert srv.compile_counts["decode"] == 1, srv.compile_counts
    # routing telemetry surfaced and self-consistent
    assert srv.stats["moe_routed"] > 0
    assert srv.stats["moe_routed"] == (sum(srv.stats["moe_expert_tokens"])
                                       + srv.stats["moe_dropped"])
    return srv

# deepseek arms: EP vs the SINGLE-DEVICE server. Its reduced config is MHA
# (kv heads == heads), which keeps the mesh attention bit-exact, so any
# mismatch here is the MoE dispatch's fault.
for arch, cap in (("deepseek-moe-16b", None),     # w-ternary: narrow EP row
                  ("deepseek-moe-16b", 0.5)):     # force capacity drops
    cfg = get_config(arch).reduced()
    if cap is not None:
        cfg = dataclasses.replace(cfg, capacity_factor=cap)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)
               for n in PROMPT_LENS]
    ctx = ModelCtx(mode="serve", backend="jnp", dtype=jnp.float32)
    ref = serve(cfg, sparams, ctx, prompts, None)
    want = {r.rid: r.out for r in ref.completed}
    ep_srv = serve(cfg, sparams, ctx, prompts, mesh)
    got = {r.rid: r.out for r in ep_srv.completed}
    assert got == want, ("EP serve diverged", arch, cap, got, want)
    # stats identical too: routing (and drops) are shard-count independent
    for k in ("moe_routed", "moe_dropped", "moe_expert_tokens"):
        assert ep_srv.stats[k] == ref.stats[k], (k, ep_srv.stats, ref.stats)
    if cap is not None:
        assert ep_srv.stats["moe_dropped"] > 0   # the drop arm really drops
    print("OK", arch, cap, ep_srv.stats["moe_dropped"], flush=True)

# phi3.5 arm: EP vs the DENSE-VMAP server ON THE SAME MESH. Its reduced
# config is GQA with kv=2 — the kv-head count doesn't divide model=4, and
# on the CPU SPMD backend that geometry (under the weight-only w-* policies,
# whose bf16 activations can't absorb ulp noise the way int8 requant does)
# makes mesh attention diverge from single-device at the value level with
# NO MoE code in the loop (reproduces on llama3.2 reduced, kv=2 + w-ternary,
# n_experts=0; kv=4 or wide policies are exact). See docs/SERVING.md
# §Known constraints. The MoE contract still holds shard-for-shard: the
# grouped EP dispatch must match the replicated dense expert vmap bit for
# bit under the identical mesh, stats included.
cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
params = transformer.init(jax.random.PRNGKey(0), cfg)
sparams = transformer.pack_for_serve(params, cfg)
prompts = [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)
           for n in PROMPT_LENS]
ctx = ModelCtx(mode="serve", backend="jnp", dtype=jnp.float32)
ref = serve(cfg, sparams, ctx, prompts, mesh, moe_ep=False)
want = {r.rid: r.out for r in ref.completed}
ep_srv = serve(cfg, sparams, ctx, prompts, mesh)
got = {r.rid: r.out for r in ep_srv.completed}
assert got == want, ("EP vs dense-vmap diverged", got, want)
for k in ("moe_routed", "moe_dropped", "moe_expert_tokens"):
    assert ep_srv.stats[k] == ref.stats[k], (k, ep_srv.stats, ref.stats)
print("OK phi3.5-moe ep-vs-dense", ep_srv.stats["moe_dropped"], flush=True)
print("MOE_SERVE_OK")
'''


def test_ep_qgemm_token_exact_vs_dense_vmap():
    """Grouped EP qgemm == dense expert vmap, bit for bit, for every
    registered cell on both parallels (jnp + pallas spot-check), with
    fallback on non-dividing expert counts and narrow-cell row EP allowed
    (disjoint-assembly psum)."""
    r = _run(SCRIPT_QGEMM)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "EP_QGEMM_OK" in r.stdout, r.stdout[-2000:]


def test_ep_serve_token_exact_vs_single_device():
    """EP(model=4) paged serve, token for token AND stat for stat, on a
    forced-8-device CPU mesh: deepseek-moe (plus a drop-forcing capacity
    arm) against the single-device server; phi3.5-moe against the
    dense-expert-vmap server on the same mesh (its kv=2 GQA geometry hits a
    pre-existing mesh-vs-single attention divergence with no MoE code in
    the loop — see the in-script comment)."""
    r = _run(SCRIPT_SERVE)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "MOE_SERVE_OK" in r.stdout, r.stdout[-2000:]
