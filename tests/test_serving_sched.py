"""Scheduler lockdown: prefix sharing, copy-on-write, preemption + swap must
all be TOKEN-EXACT against the sequential single-request oracle.

Correctness here is adversarial by construction: a missed CoW fork lets one
request's decode writes corrupt a co-owner's shared page; a swap that drops
or rounds a byte resumes a request in a subtly different state; a refcount
bug hands a live page to a newcomer. None of those look like crashes — they
look like *plausible but different tokens*, so every test demands bit-exact
token equality, not a tolerance.

Determinism notes that make exactness possible:
  * sampling is stateless (`models.common.sample_token`, rng keyed by
    (seed, token index)) — a request's token i is a pure function of its
    logits, seed and i, independent of batching/preemption history;
  * a token's KV depends only on the token-id prefix (causal attention, no
    dropout at serve), so shared pages hold bit-identical KV by definition;
  * swap slabs are numpy copies in the pool dtype — no conversion.

Under pure greedy decode two requests with identical prompts emit identical
tokens, which would make a broken CoW *invisible* (the corrupting writes
write the same bytes). The CoW tests therefore sample with temperature > 0
and distinct seeds: continuations diverge right at the shared boundary page,
and a missing fork shows up as a token mismatch.
"""
import dataclasses
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import PREEMPTED, RUNNING, WAITING, Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx, sample_token

MAX_NEW = 4
CACHE_LEN = 32
PAGE_SIZE = 4
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@functools.lru_cache(maxsize=None)
def _built(policy: str):
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(), policy=policy)
    sp = transformer.build_specs(cfg)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    return cfg, sp, sparams


def _shared_prefix_prompts(cfg, *, prefix_len=8, tails=(2, 2, 2), seed=17,
                           duplicate_first=True):
    """Prompts sharing a common prefix; optionally one exact duplicate (the
    duplicate aliases the *partial* boundary page too — the CoW case)."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, cfg.vocab, size=(prefix_len,)).astype(np.int32)
    prompts = [np.concatenate([common,
                               rng.integers(0, cfg.vocab, size=(t,)).astype(np.int32)])
               for t in tails]
    if duplicate_first:
        prompts.append(prompts[0].copy())
    return prompts


def _reference(cfg, sp, sparams, ctx, prompt, max_new, *, temperature=0.0,
               seed=0):
    """Single-request decode on the seed-validated contiguous scalar-pos
    path, sampling with the same stateless rng the server uses."""
    logits, cache = transformer.prefill(sparams, jnp.asarray(prompt)[None], sp,
                                        ctx, cache_len=CACHE_LEN)
    out = [sample_token(np.asarray(logits[0, -1]), temperature, seed, 0)]
    pos = len(prompt)
    while len(out) < max_new:
        l, cache = transformer.decode_step(
            sparams, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(pos), sp, ctx)
        out.append(sample_token(np.asarray(l[0, 0]), temperature, seed,
                                len(out)))
        pos += 1
    return out


def _serve(cfg, sparams, ctx, reqs, **kw):
    srv = Server(cfg, sparams, cache_len=CACHE_LEN, page_size=PAGE_SIZE,
                 paged=True, ctx=ctx, **kw)
    for r in reqs:
        srv.submit(r)
    srv.run()
    assert len(srv.completed) == len(reqs)
    # the scheduler always drains completely and leaks nothing
    assert not srv.preempted and not srv._swap
    assert srv.pt.free_pages == srv.pt.usable_pages
    return srv


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("policy", ["binary", "ternary", "int8"])
def test_share_and_preempt_token_exact(policy, backend):
    """Shared-prefix traffic (incl. one exact-duplicate prompt) through a
    page-tight server with --prefix-share AND --preempt: token-for-token
    identical to the sequential oracle for all three W&A policies on both
    qgemm backends — while pages really alias and the jit discipline holds."""
    cfg, sp, sparams = _built(policy)
    ctx = ModelCtx(mode="serve", backend=backend, dtype=jnp.float32)
    prompts = _shared_prefix_prompts(cfg)
    want = [_reference(cfg, sp, sparams, ctx, p, MAX_NEW) for p in prompts]
    reqs = [Request(i, p, MAX_NEW) for i, p in enumerate(prompts)]
    # 8 usable pages: every request's lifetime alone needs 4, so nothing
    # would co-run without sharing; sharing keeps 2+ slots busy
    srv = _serve(cfg, sparams, ctx, reqs, slots=3, num_pages=9,
                 prefix_share=True, preempt=True)
    assert srv.stats["shared_pages"] > 0, srv.stats
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (policy, backend, i, got[i], w)
    # jit discipline survives sharing/CoW/preemption: one decode signature,
    # bucketed prefill, at most one CoW-copy signature
    assert srv.compile_counts["decode"] == 1, srv.compile_counts
    assert srv.compile_counts["cow"] <= 1, srv.compile_counts
    assert srv.compile_counts["prefill"] <= len(srv.buckets)


def test_cow_isolates_sampled_divergence():
    """Three requests with IDENTICAL prompts but different sampling seeds:
    admission aliases all their pages (including the partial boundary page),
    the first divergent decode write forces a CoW fork, and every request
    must still match its own solo oracle. Without the fork, co-owners would
    overwrite each other's boundary page with *different* bytes — this is
    the test a missing/broken copy-on-write cannot pass."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    reqs = [Request(i, prompt.copy(), 6, temperature=1.0, seed=100 + i)
            for i in range(3)]
    srv = _serve(cfg, sparams, ctx, reqs, slots=3, prefix_share=True)
    assert srv.stats["shared_pages"] >= 2, srv.stats   # full + partial page
    assert srv.stats["cow_forks"] >= 1, srv.stats
    outs = {r.rid: r.out for r in srv.completed}
    assert len({tuple(o) for o in outs.values()}) == 3, \
        f"seeds should diverge: {outs}"
    for i in range(3):
        want = _reference(cfg, sp, sparams, ctx, prompt, 6,
                          temperature=1.0, seed=100 + i)
        assert outs[i] == want, (i, outs[i], want)


@pytest.mark.parametrize("chunk_tokens", [3, 5])
def test_chunked_share_preempt_token_exact(chunk_tokens):
    """Chunked prefill under the full scheduler gauntlet: shared-prefix
    traffic (incl. an exact duplicate) with --prefix-share AND --preempt
    over a page-tight pool, sampling at temperature > 0 so CoW divergence
    is forced — token-exact vs the sequential oracle. The chunk sizes do
    not divide the prompt lengths (padded final chunk) and straddle page
    boundaries; deferred share-index registration must still alias pages
    (shared_pages > 0) even though the prefix is built chunk by chunk, and
    the whole run compiles zero prefill-bucket signatures."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompts = _shared_prefix_prompts(cfg)
    want = [_reference(cfg, sp, sparams, ctx, p, MAX_NEW, temperature=0.9,
                       seed=50 + i) for i, p in enumerate(prompts)]
    reqs = [Request(i, p, MAX_NEW, temperature=0.9, seed=50 + i)
            for i, p in enumerate(prompts)]
    srv = _serve(cfg, sparams, ctx, reqs, slots=3, num_pages=9,
                 prefix_share=True, preempt=True, chunk_tokens=chunk_tokens)
    assert srv.stats["chunk_ticks"] > 0, srv.stats
    assert srv.stats["shared_pages"] > 0, srv.stats
    assert srv.compile_counts["prefill"] == 0, srv.compile_counts
    assert srv.compile_counts["chunk"] == 1, srv.compile_counts
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (chunk_tokens, i, got[i], w)


def test_preemption_swaps_out_and_resumes_token_exact():
    """A pool too small for two decode lifetimes with --preempt: both
    requests admit immediately (prompt-only admission), the pool runs dry
    mid-decode, the younger request is swapped out to the host slab and
    later swapped back in — and both outputs are bit-identical to the
    sequential oracle. Also checks the request-state lifecycle."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
               for _ in range(2)]
    max_new = 12    # lifetime 8+12-1=19 tokens -> 5 pages each; 6 usable
    want = [_reference(cfg, sp, sparams, ctx, p, max_new) for p in prompts]
    reqs = [Request(i, p, max_new) for i, p in enumerate(prompts)]
    for r in reqs:
        assert r.state == WAITING or r.state == "WAITING"
    srv = _serve(cfg, sparams, ctx, reqs, slots=2, num_pages=7, preempt=True)
    assert srv.stats["preemptions"] >= 1, srv.stats
    assert srv.stats["resumes"] == srv.stats["preemptions"], srv.stats
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)
    assert all(r.state == RUNNING for r in srv.completed)  # resumed to done
    # prompt-only admission really co-ran them: some fused tick carried both
    assert any(len(t) > 1 for t in srv.pos_trace), srv.pos_trace
    # ... which the conservative reservation (no --preempt) cannot do on the
    # same pool: it serializes the two requests — the can_admit(reclaimable=)
    # fix is exactly the gap between these two schedules
    srv2 = _serve(cfg, sparams, ctx,
                  [Request(i, p, max_new) for i, p in enumerate(prompts)],
                  slots=2, num_pages=7)
    assert all(len(t) == 1 for t in srv2.pos_trace)
    assert {r.rid: r.out for r in srv2.completed} == got


def test_preempted_state_is_observable_midflight():
    """While the pool is dry the victim request is parked in state PREEMPTED
    with its swap slab recorded; pages come back only at resume."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(i, p, 12) for i, p in enumerate(prompts)]
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=7, preempt=True, ctx=ctx)
    for r in reqs:
        srv.submit(r)
    seen_preempted = False
    for _ in range(200):
        alive = srv.step()
        if any(r.state == PREEMPTED for r in reqs):
            seen_preempted = True
            victim = next(r for r in reqs if r.state == PREEMPTED)
            assert victim.rid in srv._swap
            assert victim in srv.preempted
        if not alive:
            break
    assert seen_preempted
    assert len(srv.completed) == 2


def test_prefix_share_throughput_on_shared_workload():
    """The capacity win that motivates the tentpole: on a shared-prefix
    workload over a constrained pool, --prefix-share admits all requests
    concurrently where the no-sharing baseline serializes waves — >= 1.5x
    admitted throughput (tokens per fused decode tick) at identical tokens."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(31)
    common = rng.integers(0, cfg.vocab, size=(16,)).astype(np.int32)
    prompts = [np.concatenate(
        [common, rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)])
        for _ in range(4)]
    max_new = 6        # lifetime 18+6-1=23 tokens -> 6 pages/request

    def run(share):
        reqs = [Request(i, p, max_new) for i, p in enumerate(prompts)]
        srv = _serve(cfg, sparams, ctx, reqs, slots=4, num_pages=13,
                     prefix_share=share)
        toks = sum(len(r.out) for r in srv.completed)
        return srv, toks / max(len(srv.pos_trace), 1)

    base_srv, base_tpt = run(False)
    share_srv, share_tpt = run(True)
    # identical greedy tokens either way — sharing is a pure capacity win
    assert ({r.rid: r.out for r in share_srv.completed}
            == {r.rid: r.out for r in base_srv.completed})
    assert share_srv.stats["shared_pages"] >= 12, share_srv.stats  # 4 pages x 3
    ratio = share_tpt / base_tpt
    assert ratio >= 1.5, (ratio, base_tpt, share_tpt)
    # and it really was concurrency: all four slots decoded in one tick
    assert max(len(t) for t in share_srv.pos_trace) == 4
    assert max(len(t) for t in base_srv.pos_trace) <= 2


def test_submit_accepts_exact_fit_pool_with_sharing():
    """--prefix-share must not shrink the servable envelope: a request whose
    lifetime needs exactly the whole pool is accepted and served (a solo run
    can never need a CoW fork — refcount > 1 requires a live co-owner slot —
    so there is no hidden +1 page)."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(37)
    prompt = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    max_new = 9                      # 8 + 9 - 1 = 16 tokens -> all 4 pages
    want = _reference(cfg, sp, sparams, ctx, prompt, max_new)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=5, prefix_share=True,
                 preempt=True, ctx=ctx)
    srv.submit(Request(0, prompt, max_new))   # must not raise
    srv.run()
    assert srv.completed[0].out == want
    assert srv.pt.free_pages == srv.pt.usable_pages


def test_windowed_scanned_arch_swaps_rings_and_mid_leaves_exact():
    """Mixed local/attn arch with a scanned mid-stack (gemma reduced,
    window=8): preemption must swap window RING slabs and recurrent per-slot
    rows alongside the paged pool, and the scanned `mid` cache leaves carry a
    leading (n_periods,) dim through CoW copy / swap gather / swap scatter —
    the llama-reduced oracles (2 unrolled layers) never touch that branch.
    Token-exact vs the sequential reference through ring wraparound, with
    prefix sharing on the attn layers' pages."""
    cfg = dataclasses.replace(get_config("gemma3-4b").reduced(),
                              policy="ternary", window=8)
    sp = transformer.build_specs(cfg)
    assert sp.n_periods >= 1          # the scanned mid-stack really exists
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(29)
    common = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    prompts = [np.concatenate(
        [common, rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)])
        for _ in range(2)]
    max_new = 10     # decode crosses the window=8 ring boundary
    want = [_reference(cfg, sp, sparams, ctx, p, max_new) for p in prompts]
    reqs = [Request(i, p, max_new) for i, p in enumerate(prompts)]
    # 7 usable pages vs 5-page lifetimes: dries mid-decode -> swap
    srv = _serve(cfg, sparams, ctx, reqs, slots=2, num_pages=8,
                 prefix_share=True, preempt=True)
    assert srv.stats["preemptions"] >= 1, srv.stats
    assert srv.stats["shared_pages"] >= 1, srv.stats
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)


def test_fifo_priority_and_explicit_priority_classes():
    """The victim rule: preemption evicts the lowest-priority running request
    (priority class first, youngest rid within a class), so a high-priority
    latecomer can claim pages from a low-priority incumbent and still every
    request completes token-exactly."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
               for _ in range(3)]
    max_new = 10
    want = [_reference(cfg, sp, sparams, ctx, p, max_new) for p in prompts]
    # rid 2 outranks the incumbents
    reqs = [Request(0, prompts[0], max_new),
            Request(1, prompts[1], max_new),
            Request(2, prompts[2], max_new, priority=1)]
    srv = _serve(cfg, sparams, ctx, reqs, slots=3, num_pages=8, preempt=True)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)
    assert srv.stats["preemptions"] >= 1, srv.stats


SCRIPT_TP = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8
from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx

mesh = jax.make_mesh((2, 4), ("data", "model"))
CACHE_LEN, PAGE_SIZE = 32, 4
# 10 total pages (incl. scratch page 0): even, so the pool's page axis
# divides data=2 and really device-shards (an odd pool falls back to
# replicated) — and tight enough that decode growth dries the pool and
# forces preemption + swap against the sharded pool.
# slots=2 divides data=2: a decode batch the data axis does NOT divide
# miscompiles on the CPU SPMD partitioner (seed-reproducible with the plain
# paged server at slots=3 — same landmine family as the head-axis
# with_sharding_constraint note in models/common.py; see docs/SERVING.md).
NUM_PAGES = 10

cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(), policy="ternary")
params = transformer.init(jax.random.PRNGKey(0), cfg)
sparams = transformer.pack_for_serve(params, cfg)
rng = np.random.default_rng(41)
common = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
prompts = [np.concatenate([common,
                           rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)])
           for _ in range(3)]
# exact duplicate FIRST: r0/r1 co-run as sharers of the partial boundary
# page, so the first decode tick must CoW-fork against the sharded pool
prompts.insert(1, prompts[0].copy())

# Greedy on purpose: the TP exactness contract is token-level (argmax) —
# cross-shard float reduction layouts differ in low bits, so sampled draws
# may flip under a mesh. CoW still fires (the duplicate prompt aliases the
# boundary page and forks on its first decode write); the sampled-divergence
# CoW oracle runs single-device in test_cow_isolates_sampled_divergence.
def serve(mesh_):
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=NUM_PAGES, ctx=ctx, mesh=mesh_,
                 prefix_share=True, preempt=True)
    if mesh_ is not None:
        assert srv.cache["first"]["k"].sharding.spec[0] == "data"
        assert isinstance(srv.pt.table, np.ndarray)      # host-global
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, 14))
    srv.run()
    assert len(srv.completed) == len(prompts)
    assert srv.compile_counts["decode"] == 1, srv.compile_counts
    assert srv.stats["shared_pages"] > 0, srv.stats
    assert srv.pt.free_pages == srv.pt.usable_pages
    return srv

ctx = ModelCtx(mode="serve", dtype=jnp.float32)
single = serve(None)
want = {r.rid: r.out for r in single.completed}
tp = serve(mesh)
got = {r.rid: r.out for r in tp.completed}
assert got == want, ("TP sched serve diverged", got, want)
# the host-side scheduler made identical decisions on both (greedy tokens
# equal => same admission/fork/preempt trace), and the CoW + swap paths
# really ran against the data-sharded pool
assert tp.stats == single.stats, (tp.stats, single.stats)
assert tp.stats["cow_forks"] >= 1, tp.stats
assert tp.stats["preemptions"] >= 1, tp.stats
print("stats:", tp.stats)
print("SCHED_TP_OK")
'''


def test_mesh_share_preempt_token_exact_vs_single_device():
    """Forced-8-device (data=2, model=4) mesh: --prefix-share --preempt
    serving — CoW forks and swap in/out against the data-sharded pool —
    stays token-exact (greedy) vs the single-device scheduler, with an
    identical host-side scheduling trace. Subprocess so the device-count
    flag can't leak into the suite (same pattern as tests/test_serving_tp.py)."""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT_TP],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SCHED_TP_OK" in r.stdout, r.stdout[-2000:]
