"""Multi-tenant serving lockdown: N tenants (different archs x precision
policies) co-scheduled on ONE shared page pool must each stay token-exact
against their own single-model sequential oracle — with prefix sharing,
preemption, and the tiered prefix cache all enabled — and a cold restart
must re-admit previously cached prefixes from the disk tier without
re-prefilling.

This is the multi-tenant extension of test_serving's batched-equals-
sequential oracle: the failure class it catches is cross-tenant aliasing
(one model's KV pages mapped into another's table because the share index
keys weren't namespace-disjoint) and allocator races (a tenant's page
reclaimed or evicted while another tenant's admission was about to map it).
Run in f32 so both paths compute identical algebra.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.cache_tiers import PageStore
from repro.launch.multi_serve import MultiServer, TenantSpec
from repro.models import registry, transformer
from repro.models.common import ModelCtx

MAX_NEW = 4
CACHE_LEN = 32
PAGE = 4

# two archs (pure-attn llama vs windowed gemma) x two precision policies
TENANTS = [
    TenantSpec(model_id="llama#0", arch="llama3.2-3b", policy="ternary",
               slots=2, cache_len=CACHE_LEN, weight=2, priority=1,
               reduced=True),
    TenantSpec(model_id="gemma#1", arch="gemma3-4b", policy="w-ternary",
               slots=2, cache_len=CACHE_LEN, weight=1, priority=0,
               reduced=True),
]


@functools.lru_cache(maxsize=None)
def _entry(arch: str, policy: str):
    cfg, packed, _ = registry.build_serve_entry(arch, policy=policy,
                                                reduced=True,
                                                dtype=jnp.float32)
    return cfg, transformer.build_specs(cfg), packed


def _oracle(arch, policy, prompt, max_new=MAX_NEW):
    """Single-request contiguous scalar-pos greedy decode (the seed-
    validated reference path), per tenant."""
    cfg, sp, sparams = _entry(arch, policy)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    logits, cache = transformer.prefill(sparams, jnp.asarray(prompt)[None],
                                        sp, ctx, cache_len=CACHE_LEN)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(out) < max_new:
        l, cache = transformer.decode_step(
            sparams, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(pos), sp, ctx)
        out.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    return out


def _traffic(seed=7, n=3):
    """Per-tenant prompt lists: a stable page-aligned common prefix (so the
    share index and the disk tier have something to hit) + mixed-length
    random tails. Both tenants get the SAME token streams — the namespaced
    keys must keep them from ever aliasing a page."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, 500, size=(PAGE,))
    prompts = []
    for i in range(n):
        tail = rng.integers(0, 500, size=(2 + 3 * i,))
        prompts.append(np.concatenate([head, tail]).astype(np.int32))
    return prompts


def _serve_all(ms, prompts):
    rids = {}
    for t in ms.tenants:
        for p in prompts:
            rids.setdefault(t.model_id, []).append(
                ms.submit(t.model_id, p, MAX_NEW))
    ms.run()
    return rids


def _assert_exact(ms, rids, prompts):
    for t in ms.tenants:
        done = {r.rid: r.out for r in ms.servers[t.model_id].completed}
        for rid, p in zip(rids[t.model_id], prompts):
            want = _oracle(t.arch, t.policy, p)
            assert done[rid] == want, (t.model_id, rid, done[rid], want)


def test_cotenants_token_exact_shared_pool_tiered(tmp_path):
    """Acceptance gate: 2 archs x 2 policies co-scheduled with prefix-share
    + preempt + tiering on an oversubscribed shared pool, every tenant
    token-exact vs its own oracle."""
    store = PageStore(host_capacity=4, disk_dir=tmp_path)
    # full provisioning would be 4 slots x 8 pages + 1 = 33; 25 forces the
    # tenants to actually compete for pages
    ms = MultiServer(TENANTS, page_size=PAGE, num_pages=25,
                     prefix_share=True, preempt=True, tier=store,
                     dtype=jnp.float32)
    prompts = _traffic()
    rids = _serve_all(ms, prompts)
    _assert_exact(ms, rids, prompts)
    st = ms.stats()
    for t in ms.tenants:
        assert st[t.model_id]["completed"] == len(prompts)
        # per-model jit discipline holds while co-scheduled
        assert st[t.model_id]["jit_signatures"] <= 12
    # the identical token streams shared pages only WITHIN each namespace
    assert st["llama#0"]["shared_pages"] >= 1
    assert st["gemma#1"]["shared_pages"] >= 1
    # pool drains clean: nothing live (parked pages count as free supply),
    # and the retired prefixes really did stay resident in the device tier
    pool = st["pool"]
    assert pool["live_pages"] == 0
    assert pool["cached_pages"] >= 1


def test_cold_restart_reuses_disk_tier(tmp_path):
    """Kill-and-restart: a fresh MultiServer over the same slab directory
    re-admits prefixes from the disk tier — the pure-attn tenant skips
    prefill outright (first token from one chunk step), the windowed tenant
    (exact_prefill) still promotes and maps the pages — and both stay
    token-exact."""
    prompts = _traffic()
    ms1 = MultiServer(TENANTS, page_size=PAGE, prefix_share=True,
                      tier=PageStore(host_capacity=2, disk_dir=tmp_path),
                      dtype=jnp.float32)
    rids1 = _serve_all(ms1, prompts)
    _assert_exact(ms1, rids1, prompts)
    ms1.flush_tier()                      # clean shutdown: park -> disk
    assert ms1.pt.store.stats["disk_writes"] >= 1

    ms2 = MultiServer(TENANTS, page_size=PAGE, prefix_share=True,
                      tier=PageStore(host_capacity=2, disk_dir=tmp_path),
                      dtype=jnp.float32)
    rids2 = _serve_all(ms2, prompts)
    _assert_exact(ms2, rids2, prompts)
    st = ms2.stats()
    for t in ms2.tenants:
        row = st[t.model_id]
        assert row["tier_hits_host"] + row["tier_hits_disk"] >= 1, row
    # the pure-attn tenant's fully-covered prompt never re-prefilled
    assert st["llama#0"]["prefill_skips"] >= 1
    # windowed + exact_prefill cannot skip (ring slab isn't paged): the
    # guard must have kept it on the re-prefill path, not broken exactness
    assert st["gemma#1"]["prefill_skips"] == 0


def test_wrr_rotation_orders_claims_by_weight():
    """The weighted cycle gives a weight-2 tenant first claim twice as
    often, rotates fairly, and never skips a tenant in a tick."""
    ms = object.__new__(MultiServer)      # rotation logic only, no models
    ms._cycle = ["a", "a", "b"]
    ms._rr = 0
    orders = [ms._tick_order() for _ in range(6)]
    assert all(sorted(o) == ["a", "b"] for o in orders)
    firsts = [o[0] for o in orders]
    assert firsts == ["a", "a", "b"] * 2
    assert ms._rr == 0                    # full rotation wraps


def test_priority_class_reclaims_across_tenants():
    """Under pool pressure with --preempt, a higher-priority tenant's
    admission preempts a strictly-lower-priority co-tenant's RUNNING slot
    (cross-tenant reclaim), and BOTH tenants still finish token-exact."""
    tenants = [
        TenantSpec(model_id="lo#0", arch="llama3.2-3b", policy="ternary",
                   slots=1, cache_len=CACHE_LEN, priority=0, reduced=True),
        TenantSpec(model_id="hi#1", arch="llama3.2-3b", policy="ternary",
                   slots=1, cache_len=CACHE_LEN, priority=1, reduced=True),
    ]
    # 8 usable pages; each request's lifetime needs 5 (14 prompt + 4 new)
    ms = MultiServer(tenants, page_size=PAGE, num_pages=9, preempt=True,
                     dtype=jnp.float32)
    rng = np.random.default_rng(3)
    p_lo = rng.integers(0, 500, size=(14,)).astype(np.int32)
    p_hi = rng.integers(0, 500, size=(14,)).astype(np.int32)
    r_lo = ms.submit("lo#0", p_lo, MAX_NEW)
    # let the low-priority request admit and hold its pages first
    ms.step_all()
    r_hi = ms.submit("hi#1", p_hi, MAX_NEW)
    ms.run()
    assert ms.servers["lo#0"].stats["preemptions"] >= 1
    assert ms.servers["lo#0"].stats["resumes"] >= 1
    done_lo = {r.rid: r.out for r in ms.servers["lo#0"].completed}
    done_hi = {r.rid: r.out for r in ms.servers["hi#1"].completed}
    assert done_lo[r_lo] == _oracle("llama3.2-3b", "ternary", p_lo)
    assert done_hi[r_hi] == _oracle("llama3.2-3b", "ternary", p_hi)


def test_queue_cap_and_slo_counters():
    """max_queue drops excess submissions (counted, returning None) and the
    SLO record tracks submitted/dropped/completed with TTFT/ITL
    percentiles for what ran."""
    tenants = [TenantSpec(model_id="m#0", arch="llama3.2-3b",
                          policy="ternary", slots=1, cache_len=CACHE_LEN,
                          max_queue=1, reduced=True)]
    ms = MultiServer(tenants, page_size=PAGE, dtype=jnp.float32)
    p = np.arange(5, dtype=np.int32)
    rids = [ms.submit("m#0", p, MAX_NEW) for _ in range(3)]
    assert rids[0] is not None and rids[1] is None and rids[2] is None
    ms.run()
    row = ms.stats()["m#0"]
    assert row["submitted"] == 3
    assert row["dropped"] == 2
    assert row["completed"] == 1
    assert row["ttft_ticks_p50"] >= 1     # first token needs >= 1 tick
    assert row["itl_s_p50"] >= 0.0
