"""Property tests on model-level invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import attention, moe
from repro.models.common import ModelCtx

F32 = ModelCtx(mode="train", dtype=jnp.float32)


# -- blockwise attention == blockless reference -------------------------------

@given(st.integers(0, 10**6), st.sampled_from([(64, 64), (128, 64), (128, 128)]),
       st.booleans())
@settings(max_examples=10, deadline=None)
def test_blockwise_attention_matches_reference(seed, tq_tk, causal):
    tq, tk = tq_tk
    b, hk, g, dh = 2, 2, 2, 16
    h = hk * g
    ks = jax.random.split(jax.random.PRNGKey(seed % 2**31), 3)
    q = jax.random.normal(ks[0], (b, tq, h, dh))
    k = jax.random.normal(ks[1], (b, tk, hk, dh))
    v = jax.random.normal(ks[2], (b, tk, hk, dh))
    got = attention.blockwise_attention(q, k, v, causal=causal,
                                        q_block=32, kv_block=32)
    # blockless reference
    mask = jnp.ones((b, tq, tk), bool)
    if causal:
        mask &= (jnp.arange(tq)[:, None] >= jnp.arange(tk)[None, :])[None]
    want = attention._gqa_scores_blockless(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(0, 10**6), st.sampled_from([16, 32]))
@settings(max_examples=6, deadline=None)
def test_window_attention_matches_reference(seed, window):
    b, tq, hk, g, dh = 1, 128, 2, 1, 16
    h = hk * g
    ks = jax.random.split(jax.random.PRNGKey(seed % 2**31), 3)
    q = jax.random.normal(ks[0], (b, tq, h, dh))
    k = jax.random.normal(ks[1], (b, tq, hk, dh))
    v = jax.random.normal(ks[2], (b, tq, hk, dh))
    got = attention.blockwise_attention(q, k, v, causal=True, window=window,
                                        q_block=32, kv_block=32)
    pos = jnp.arange(tq)
    mask = ((pos[:, None] >= pos[None, :])
            & (pos[:, None] - pos[None, :] < window))[None]
    want = attention._gqa_scores_blockless(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_cp_single_level_matches_two_level():
    """cp=True (single kv scan) == cp=False (two-level) — same math."""
    b, t, hk, g, dh = 2, 512, 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, t, hk * g, dh))
    k = jax.random.normal(ks[1], (b, t, hk, dh))
    v = jax.random.normal(ks[2], (b, t, hk, dh))
    a = attention.blockwise_attention(q, k, v, causal=True, cp=False)
    b_ = attention.blockwise_attention(q, k, v, causal=True, cp=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-4)


# -- MoE dispatch invariants ---------------------------------------------------

def _moe_setup(capacity_factor=8.0, seed=0):
    cfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                              capacity_factor=capacity_factor)
    pol = get_policy("none")
    specs = moe.moe_specs(cfg, pol)
    params = moe.moe_init(jax.random.PRNGKey(seed), specs)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model)) * 0.3
    return params, x, specs


def test_moe_identity_experts_preserve_combine_weights():
    """With no token drops, combine weights per token sum to ~1 (top-k
    renormalized) — checked through the output magnitude of identity experts."""
    params, x, specs = _moe_setup(capacity_factor=8.0)
    y, aux = moe.moe_apply(params, x, specs, F32)
    assert y.shape == x.shape
    assert np.isfinite(float(aux["loss"]))
    # aux loss near its e*sum(f*p) ~ 1 optimum for near-uniform routing
    assert 0.5 < float(aux["loss"]) < 4.0
    # routing-stat side-car: every kept assignment counted, none dropped at
    # the smoke capacity factor
    b, s = x.shape[:2]
    assert int(aux["dropped"]) == 0
    assert int(np.sum(np.asarray(aux["expert_tokens"]))) == b * s * specs.top_k


@given(st.integers(0, 10**5))
@settings(max_examples=5, deadline=None)
def test_moe_low_capacity_drops_bounded(seed):
    """Dropping capacity only removes tokens — output norm can't exceed the
    no-drop output norm by more than numerics."""
    p_hi, x, s_hi = _moe_setup(8.0, seed % 100)
    p_lo, _, s_lo = _moe_setup(0.25, seed % 100)
    y_hi, _ = moe.moe_apply(p_hi, x, s_hi, F32)
    y_lo, _ = moe.moe_apply(p_hi, x, s_lo, F32)   # same params, less capacity
    # dropped tokens produce zero expert output; shared expert unaffected
    n_hi = float(jnp.linalg.norm(y_hi))
    n_lo = float(jnp.linalg.norm(y_lo))
    assert n_lo <= n_hi * 1.05 + 1e-3


def test_moe_grads_reach_router_and_experts():
    params, x, specs = _moe_setup()
    def loss(p):
        y, aux = moe.moe_apply(p, x, specs, F32)
        return jnp.sum(y ** 2) + 0.01 * aux["loss"]
    g = jax.grad(loss)(params)
    assert float(jnp.sum(jnp.abs(g["router"]["w"]))) > 0
    assert float(jnp.sum(jnp.abs(g["up"]["w"]))) > 0
