"""Chunkwise-parallel mLSTM == sequential-scan oracle (§Perf, xlstm train)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import ssm
from repro.models.common import ModelCtx

F32 = ModelCtx(mode="train", dtype=jnp.float32)


def _setup(b=2, t=128, seed=0):
    cfg = get_config("xlstm-125m").reduced()
    pol = get_policy("none")
    specs = ssm.mlstm_specs(cfg, pol)
    params = ssm.mlstm_init(jax.random.PRNGKey(seed), cfg, specs)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t, cfg.d_model)) * 0.5
    return params, x, specs


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_chunkwise_matches_scan(chunk):
    params, x, specs = _setup(t=128)
    y_seq = ssm.mlstm_apply(params, x, specs, F32, impl="scan")
    y_chk = ssm.mlstm_apply(params, x, specs, F32, impl="chunkwise", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10**6))
@settings(max_examples=5, deadline=None)
def test_chunkwise_matches_scan_property(seed):
    """Property: equality holds across random weights/inputs (incl. the
    stabilizer path — gates get a +/-3 shift to stress exp ranges)."""
    params, x, specs = _setup(t=64, seed=seed % 1000)
    shift = (seed % 7) - 3
    params = dict(params)
    params["gates"] = {"w": params["gates"]["w"] * (1.0 + (seed % 3))}
    y_seq = ssm.mlstm_apply(params, x * (1 + shift * 0.1), specs, F32, impl="scan")
    y_chk = ssm.mlstm_apply(params, x * (1 + shift * 0.1), specs, F32,
                            impl="chunkwise", chunk=16)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=5e-4, atol=5e-4)


def test_chunkwise_nondivisible_falls_back():
    params, x, specs = _setup(t=100)   # 100 % 64 != 0 -> scan path
    y = ssm.mlstm_apply(params, x, specs, F32, impl="chunkwise", chunk=64)
    y_seq = ssm.mlstm_apply(params, x, specs, F32, impl="scan")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), rtol=1e-6)
