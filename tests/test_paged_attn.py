"""Paged-attention decode kernel vs the jnp gather path, at the
attention-output level.

Unit bar: tight f32 allclose. The kernel's online-softmax block accumulation
is the same algebra as the gather path's dense softmax at a different
reduction/normalization order (running-max rescales, block-grouped sums,
normalize-then-dot), so bitwise equality is not attainable here by
construction; the serving oracle suites (test_serving*.py with the pallas
backend) hold the token-exact bar on the decoded-token level.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.llama3_2_3b import CONFIG as LLAMA
from repro.core.precision import get_policy
from repro.kernels import paged_attn as paged_attn_mod
from repro.kernels.dispatch import default_tune
from repro.kernels.paged_attn import (TUNE_KEY, paged_flash_decode,
                                      resolve_pages_per_block,
                                      vmem_decode_tile_bytes)
from repro.models import attention
from repro.models.attention import (KV_SCALE, _kv_dequant, _kv_quant,
                                    attn_decode, attn_init, attn_specs,
                                    init_cache_shapes)
from repro.models.common import ModelCtx

TOL = dict(rtol=2e-5, atol=2e-5)


def _gather_ref(q, k_pool, v_pool, pages, pos):
    """The attn_decode gather-path algebra, isolated (dense softmax)."""
    b, hq, dh = q.shape
    _, p_, hk, _ = k_pool.shape
    s = pages.shape[1] * p_
    kf = _kv_dequant(k_pool[pages].reshape(b, s, hk, dh), q.dtype)
    vf = _kv_dequant(v_pool[pages].reshape(b, s, hk, dh), q.dtype)
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    g = hq // hk
    qg = q.reshape(b, hk, g, dh)
    sc = jnp.einsum("bhgd,bshd->bhgs", qg, kf).astype(jnp.float32) / dh ** 0.5
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    a = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgs,bshd->bhgd", a, vf)
    return o.reshape(b, hq, dh)


def _setup(seed, b, max_pages, page_size, hk, hq, dh, int8, *,
           num_pages=None, dtype=jnp.float32):
    """Random pool + a disjoint per-row page layout + staggered positions."""
    num_pages = num_pages or (1 + b * max_pages)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hq, dh), dtype)
    if int8:
        kp = jax.random.randint(ks[1], (num_pages, page_size, hk, dh),
                                -127, 128, jnp.int8)
        vp = jax.random.randint(ks[2], (num_pages, page_size, hk, dh),
                                -127, 128, jnp.int8)
    else:
        kp = jax.random.normal(ks[1], (num_pages, page_size, hk, dh), dtype)
        vp = jax.random.normal(ks[2], (num_pages, page_size, hk, dh), dtype)
    # row r owns pages [1 + r*max_pages, ...); unallocated columns -> 0
    pos = ((jax.random.randint(ks[3], (b,), 0, max_pages * page_size)
            ).astype(jnp.int32))
    pages = np.zeros((b, max_pages), np.int32)
    for r in range(b):
        n_active = int(pos[r]) // page_size + 1
        pages[r, :n_active] = 1 + r * max_pages + np.arange(n_active)
    return q, kp, vp, jnp.asarray(pages), pos


@pytest.mark.parametrize("b,max_pages,page_size,hk,hq,dh", [
    (2, 8, 4, 4, 4, 32),      # MHA
    (3, 8, 4, 2, 4, 32),      # GQA g=2 (the reduced-llama serve geometry)
    (2, 16, 8, 1, 4, 64),     # MQA, bigger pages
])
@pytest.mark.parametrize("int8", [False, True])
def test_kernel_matches_gather(b, max_pages, page_size, hk, hq, dh, int8):
    q, kp, vp, pages, pos = _setup(b * max_pages + dh, b, max_pages,
                                   page_size, hk, hq, dh, int8)
    got = paged_flash_decode(q, kp, vp, pages, pos, pages_per_block=4,
                             kv_scale=KV_SCALE)
    want = _gather_ref(q, kp, vp, pages, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_block_size_invariance():
    q, kp, vp, pages, pos = _setup(11, 2, 8, 4, 2, 4, 32, False)
    outs = [paged_flash_decode(q, kp, vp, pages, pos, pages_per_block=bkp,
                               kv_scale=KV_SCALE) for bkp in (1, 2, 4, 8)]
    want = _gather_ref(q, kp, vp, pages, pos)
    for got in outs:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_pos_zero_and_full():
    """Edge positions: a slot with only token 0 valid, one with every page."""
    q, kp, vp, pages, _ = _setup(5, 2, 8, 4, 2, 4, 32, False)
    pages = jnp.asarray(np.tile(1 + np.arange(8, dtype=np.int32), (2, 1)))
    pos = jnp.asarray([0, 31], jnp.int32)
    got = paged_flash_decode(q, kp, vp, pages, pos, pages_per_block=4,
                             kv_scale=KV_SCALE)
    want = _gather_ref(q, kp, vp, pages, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_shared_prefix_pages():
    """Prefix sharing: one physical page in SEVERAL table rows — the kernel
    (like the gather path) must be oblivious to the aliasing."""
    q, kp, vp, _, _ = _setup(13, 3, 8, 4, 2, 8, 32, False)
    pages = np.zeros((3, 8), np.int32)
    pages[:, :2] = [1, 2]                      # shared prompt prefix
    pages[0, 2:5] = [3, 4, 5]                  # distinct tails
    pages[1, 2:4] = [6, 7]
    pages[2, 2] = 8
    pages = jnp.asarray(pages)
    pos = jnp.asarray([18, 15, 9], jnp.int32)
    got = paged_flash_decode(q, kp, vp, pages, pos, pages_per_block=2,
                             kv_scale=KV_SCALE)
    want = _gather_ref(q, kp, vp, pages, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_bf16_query():
    q, kp, vp, pages, pos = _setup(17, 2, 8, 4, 2, 4, 32, False)
    qb = q.astype(jnp.bfloat16)
    got = paged_flash_decode(qb, kp, vp, pages, pos, pages_per_block=4,
                             kv_scale=KV_SCALE)
    assert got.dtype == jnp.bfloat16
    want = _gather_ref(qb, kp, vp, pages, pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# module level: attn_decode routing (fused vs gather), window bypass, bound
# ---------------------------------------------------------------------------

CFG = LLAMA.reduced()                         # 4 heads / 2 kv heads / dh 32
POL = get_policy(CFG.policy)
SPECS = attn_specs(CFG, POL)
PARAMS = attn_init(jax.random.PRNGKey(0), CFG, SPECS, jnp.float32)
CTX_GATHER = ModelCtx(mode="train", dtype=jnp.float32, paged_attn="gather")
CTX_FUSED = dataclasses.replace(CTX_GATHER, paged_attn="fused")


def _paged_inputs(seed, b=3, max_pages=8, page_size=4, int8=False):
    hk, dh = CFG.n_kv_heads, CFG.head_dim
    num_pages = 1 + b * max_pages
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (b, 1, CFG.d_model), jnp.float32)
    cd = jnp.int8 if int8 else jnp.float32
    cache = {
        "k": _kv_quant(jax.random.normal(
            ks[1], (num_pages, page_size, hk, dh), jnp.float32), cd),
        "v": _kv_quant(jax.random.normal(
            ks[2], (num_pages, page_size, hk, dh), jnp.float32), cd),
    }
    pos = jnp.asarray([2, 13, 30], jnp.int32)[:b]
    pages = np.zeros((b, max_pages), np.int32)
    for r in range(b):
        n_active = int(pos[r]) // page_size + 1
        pages[r, :n_active] = 1 + r * max_pages + np.arange(n_active)
    return x, cache, pos, jnp.asarray(pages)


@pytest.mark.parametrize("int8", [False, True])
def test_attn_decode_fused_matches_gather(int8):
    x, cache, pos, pages = _paged_inputs(23, int8=int8)
    out_g, c_g = attn_decode(PARAMS, x, cache, pos, SPECS, CFG, CTX_GATHER,
                             pages=pages)
    out_f, c_f = attn_decode(PARAMS, x, cache, pos, SPECS, CFG, CTX_FUSED,
                             pages=pages)
    # the cache WRITE side is shared code — bitwise identical
    assert jnp.array_equal(c_g["k"], c_f["k"])
    assert jnp.array_equal(c_g["v"], c_f["v"])
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_f), **TOL)


def test_attn_decode_eager_length_bound(monkeypatch):
    """Satellite: eager callers slice the table to max(pos)//P + 1 columns
    before either read path touches it."""
    captured = {}
    real = paged_attn_mod.paged_flash_decode

    def spy(q, kp, vp, pages, pos, **kw):
        captured["width"] = pages.shape[1]
        return real(q, kp, vp, pages, pos, **kw)

    monkeypatch.setattr(paged_attn_mod, "paged_flash_decode", spy)
    x, cache, pos, pages = _paged_inputs(29)
    assert int(jnp.max(pos)) == 30 and pages.shape[1] == 8
    out_f, _ = attn_decode(PARAMS, x, cache, pos, SPECS, CFG, CTX_FUSED,
                           pages=pages)
    assert captured["width"] == int(jnp.max(pos)) // 4 + 1 == 8
    # with a short batch the bound actually bites
    pos2 = jnp.asarray([2, 6, 5], jnp.int32)
    out2, _ = attn_decode(PARAMS, x, cache, pos2, SPECS, CFG, CTX_FUSED,
                          pages=pages)
    assert captured["width"] == 2
    # and the sliced gather path agrees with the fused one
    out2_g, _ = attn_decode(PARAMS, x, cache, pos2, SPECS, CFG, CTX_GATHER,
                            pages=pages)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out2_g), **TOL)


def test_windowed_layer_bypasses_pool():
    """Window layers under a paged model keep their ring slabs: `pages` must
    be ignored entirely (reads AND writes) when window > 0."""
    w = 8
    b, hk, dh = 2, CFG.n_kv_heads, CFG.head_dim
    ks = jax.random.split(jax.random.PRNGKey(31), 3)
    x = jax.random.normal(ks[0], (b, 1, CFG.d_model), jnp.float32)
    ring = {"k": jax.random.normal(ks[1], (b, w, hk, dh), jnp.float32),
            "v": jax.random.normal(ks[2], (b, w, hk, dh), jnp.float32)}
    pos = jnp.asarray([5, 21], jnp.int32)
    pages = jnp.asarray(np.arange(2 * 8, dtype=np.int32).reshape(2, 8))
    out_np, c_np = attn_decode(PARAMS, x, ring, pos, SPECS, CFG, CTX_FUSED,
                               window=w, pages=None)
    out_pg, c_pg = attn_decode(PARAMS, x, ring, pos, SPECS, CFG, CTX_FUSED,
                               window=w, pages=pages)
    assert jnp.array_equal(out_np, out_pg)
    assert jnp.array_equal(c_np["k"], c_pg["k"])
    assert jnp.array_equal(c_np["v"], c_pg["v"])


def test_init_cache_shapes_window_stays_slab():
    paged = (64, 4)
    full = init_cache_shapes(CFG, 2, 32, 0, paged=paged)
    assert full["k"].shape == (64, 4, CFG.n_kv_heads, CFG.head_dim)
    ring = init_cache_shapes(CFG, 2, 32, 8, paged=paged)
    assert ring["k"].shape == (2, 8, CFG.n_kv_heads, CFG.head_dim)


def test_tune_table_entry():
    """The shipped TuneTable carries the paged-attn pseudo-cell."""
    tune = default_tune()
    assert TUNE_KEY in tune.tiles
    assert resolve_pages_per_block(tune) == tune.tiles[TUNE_KEY].bkq
    assert resolve_pages_per_block(None) >= 1
    # VMEM model sanity: one 4-page f32 tile at the reduced-llama geometry
    assert vmem_decode_tile_bytes(4, 2, 32, 4, 4, kv_bytes=4) > 0
