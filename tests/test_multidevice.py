"""Multi-device integration: the real train/serve paths on an 8-fake-device
host mesh (subprocess so the device-count flag can't leak into other tests)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8
from repro.configs import get_config
from repro.launch import sharding, step as step_mod
from repro.models import registry, transformer
from repro.models.common import ModelCtx
from repro.optim.adamw import adamw

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("llama3.2-3b").reduced()
sp = transformer.build_specs(cfg)
opt = adamw(3e-3)
params = transformer.init(jax.random.PRNGKey(0), cfg)
opt_state = opt.init(params)
ps = sharding.param_shardings(mesh, params)
os_ = sharding.opt_state_shardings(mesh, opt_state, ps)
params = jax.device_put(params, ps)
opt_state = jax.device_put(opt_state, os_)
ctx = ModelCtx(mode="train", act_dp=("data",), attn_cp="model")
step = step_mod.make_train_step(cfg, sp, opt, ctx=ctx, grad_shardings=ps)
jstep = jax.jit(step, donate_argnums=(0, 1))
losses = []
with mesh:
    for i in range(8):
        batch = registry.make_batch(jax.random.PRNGKey(i), cfg, 8, 64)
        params, opt_state, m = jstep(params, opt_state, batch, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
assert all(np.isfinite(l) for l in losses), losses
assert losses[-1] < losses[0], losses
print("MULTIDEVICE_TRAIN_OK", losses[0], "->", losses[-1])
'''


def test_train_on_8_device_mesh():
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIDEVICE_TRAIN_OK" in r.stdout
