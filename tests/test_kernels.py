"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import pack, qlinear
from repro.core.precision import LayerQuant
from repro.core.quantize import QuantSpec
from repro.kernels import bgemm, harness, i4gemm, i8gemm, ref, tgemm


def _rand_pm1(key, shape):
    return jnp.where(jax.random.bernoulli(key, 0.5, shape), 1.0, -1.0)


def _rand_trit(seed, shape):
    return jnp.asarray(np.random.default_rng(seed).integers(-1, 2, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# bgemm
# ---------------------------------------------------------------------------

SHAPES = [(8, 128, 64), (16, 256, 128), (32, 512, 256), (128, 1024, 128),
          (8, 96, 384)]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("impl", ["popcount", "mxu"])
def test_bgemm_matches_ref(m, k, n, impl):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(m * k + n), 4)
    xp = pack.pack_binary(_rand_pm1(k0, (m, k)))
    wp = pack.pack_binary(_rand_pm1(k1, (n, k)))
    ws = jax.random.uniform(k2, (n,), jnp.float32, 0.5, 2.0)
    as_ = jax.random.uniform(k3, (m,), jnp.float32, 0.5, 2.0)
    got = bgemm.bgemm(xp, wp, ws, as_, k=k, bm=8, bn=min(128, n),
                      bkw=min(4, k // 32), impl=impl)
    want = ref.binary_gemm_ref(xp, wp, k, ws, as_)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-2)


@given(st.integers(0, 10**6))
@settings(max_examples=5, deadline=None)
def test_bgemm_property_random_blocks(seed):
    """Property: kernel result is block-size invariant and matches oracle."""
    rng = np.random.default_rng(seed)
    m, kw, n = 8 * rng.integers(1, 4), 2 * rng.integers(1, 4), 128
    k = int(kw) * 32
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    xp = pack.pack_binary(_rand_pm1(k0, (int(m), k)))
    wp = pack.pack_binary(_rand_pm1(k1, (n, k)))
    ws = jnp.ones((n,), jnp.float32)
    as_ = jnp.ones((int(m),), jnp.float32)
    want = ref.binary_gemm_ref(xp, wp, k, ws, as_)
    for bkw in (1, int(kw)):
        got = bgemm.bgemm(xp, wp, ws, as_, k=k, bm=8, bn=128, bkw=bkw)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), rtol=1e-2)


# ---------------------------------------------------------------------------
# tgemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", SHAPES[:4])
def test_tgemm_matches_ref(m, k, n):
    xm, xs = pack.pack_ternary(_rand_trit(m + k, (m, k)))
    wm, ws_ = pack.pack_ternary(_rand_trit(n + k, (n, k)))
    wsc = jax.random.uniform(jax.random.PRNGKey(0), (n,), jnp.float32, 0.5, 2.0)
    asc = jax.random.uniform(jax.random.PRNGKey(1), (m,), jnp.float32, 0.5, 2.0)
    got = tgemm.tgemm(xm, xs, wm, ws_, wsc, asc, k=k, bm=8, bn=min(128, n),
                      bkw=min(4, k // 32))
    want = ref.ternary_gemm_ref(xm, xs, wm, ws_, k, wsc, asc)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-2, atol=1e-2)


def test_tgemm_sparsity_zero_block():
    """All-zero trits must produce exactly zero (the gating in gated-XNOR)."""
    m, k, n = 8, 128, 128
    xm, xs = pack.pack_ternary(jnp.zeros((m, k)))
    wm, ws_ = pack.pack_ternary(_rand_trit(0, (n, k)))
    got = tgemm.tgemm(xm, xs, wm, ws_, jnp.ones((n,)), jnp.ones((m,)), k=k, bm=8)
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)))) == 0.0


# ---------------------------------------------------------------------------
# i8gemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("with_bias", [False, True])
def test_i8gemm_matches_ref(m, k, n, with_bias):
    k0, k1 = jax.random.split(jax.random.PRNGKey(7))
    xq = jax.random.randint(k0, (m, k), -127, 128, jnp.int8)
    wq = jax.random.randint(k1, (k, n), -127, 128, jnp.int8)
    ws = jax.random.uniform(jax.random.PRNGKey(2), (n,), jnp.float32, 0.01, 0.1)
    as_ = jax.random.uniform(jax.random.PRNGKey(3), (m,), jnp.float32, 0.01, 0.1)
    bias = jax.random.normal(jax.random.PRNGKey(4), (n,)) if with_bias else None
    got = i8gemm.i8gemm(xq, wq, ws, as_, bias, bm=8, bn=min(128, n), bk=min(256, k))
    want = ref.i8_gemm_ref(xq, wq, ws, as_, bias)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# mixed w/a + int4 bodies (per-side storage densities through the harness)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", SHAPES[:4])
def test_wt_i8a_body_matches_ref(m, k, n):
    """w-ternary × a-int8 MacBody: trit planes blocked at K/32 words while
    the activation side is blocked at K int8 codes — one grid, two densities."""
    wm, ws_ = pack.pack_ternary(_rand_trit(n + k, (n, k)))
    xq = jax.random.randint(jax.random.PRNGKey(k), (m, k), -127, 128, jnp.int8)
    wsc = jax.random.uniform(jax.random.PRNGKey(0), (n,), jnp.float32, 0.5, 2.0)
    asc = jax.random.uniform(jax.random.PRNGKey(1), (m,), jnp.float32, 0.01, 0.1)
    got = harness.gemm(tgemm.TERNARY_W_I8A, (xq,), (wm, ws_), wsc, asc,
                       k=k, tile=harness.Tile(8, min(128, n), 2))
    want = ref.wt_i8a_gemm_ref(xq, wm, ws_, k, wsc, asc)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("m,k,n", SHAPES[:4])
@pytest.mark.parametrize("with_bias", [False, True])
def test_i4gemm_matches_ref(m, k, n, with_bias):
    rng = np.random.default_rng(m + k + n)
    codes = rng.integers(-7, 8, (n, k)).astype(np.int8)
    wq4 = pack.pack_int4(jnp.asarray(codes))
    xq = jax.random.randint(jax.random.PRNGKey(3), (m, k), -127, 128, jnp.int8)
    wsc = jax.random.uniform(jax.random.PRNGKey(4), (n,), jnp.float32, 0.01, 0.1)
    asc = jax.random.uniform(jax.random.PRNGKey(5), (m,), jnp.float32, 0.01, 0.1)
    bias = jax.random.normal(jax.random.PRNGKey(6), (n,)) if with_bias else None
    got = i4gemm.i4gemm(xq, wq4, wsc, asc, bias, k=k, bm=8, bn=min(128, n),
                        bkw=min(32, k // 8))
    want = ref.i4_gemm_ref(xq, wq4, k, wsc, asc, bias)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@given(st.integers(0, 10**6))
@settings(max_examples=5, deadline=None)
def test_int4_pack_roundtrip_property(seed):
    rng = np.random.default_rng(seed)
    k = 8 * rng.integers(1, 33)
    codes = rng.integers(-8, 8, (3, int(k))).astype(np.int8)
    words = pack.pack_int4(jnp.asarray(codes))
    assert words.shape == (3, k // 8) and words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(pack.unpack_int4_i8(words, int(k))),
                                  codes)


# ---------------------------------------------------------------------------
# ops-level dispatch: pallas backend == jnp backend at the model interface
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wprec,aprec", [("binary", "binary"), ("ternary", "ternary"),
                                         ("int8", "int8"), ("ternary", "int8"),
                                         ("int4", "int8")])
def test_qlinear_pallas_backend_matches_jnp(wprec, aprec):
    spec = qlinear.QLinearSpec(128, 128, LayerQuant(QuantSpec(wprec), QuantSpec(aprec)))
    p = qlinear.init(jax.random.PRNGKey(0), spec)
    ps = qlinear.pack_params(p, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 128)) * 0.2
    yj = qlinear.apply(ps, x, spec, mode="serve", backend="jnp", impl="popcount")
    yp = qlinear.apply(ps, x, spec, mode="serve", backend="pallas", impl="popcount")
    np.testing.assert_allclose(np.asarray(yj, np.float32), np.asarray(yp, np.float32),
                               rtol=5e-2, atol=5e-2)
