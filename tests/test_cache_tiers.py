"""Tiered prefix cache lockdown: device-LRU parking, host/disk PageStore,
and the demote/promote lifecycle of `launch.cache_tiers`.

These tests run against the page-table accounting alone (fake page images,
no model): the bytes-level token-exactness of tiered serving is locked by
tests/test_multi_serve.py; here we lock the *allocator* invariants that make
that exactness argument valid — a parked page is in no table row, page
conservation holds across every transition, eviction never takes a page an
in-flight admission is about to map, and a disk slab either round-trips
bit-exactly or is dropped on checksum failure (never served torn).
"""
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.launch.cache_tiers import PageStore, TieredPageTable, _slab_name
from repro.launch.kv_cache import prefix_keys

PAGE = 4


def _toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 500, size=(n,)).astype(np.int32)


def _store_keys(keys):
    """Store keys root->leaf for a `prefix_keys` list: the chain is the
    concatenation of every ancestor's verbatim key bytes (restart-stable,
    unlike physical-parent chaining)."""
    chain, out = b"", []
    for covered, h, kb in keys:
        chain += kb
        out.append((covered, h, chain))
    return out


def _img(tag):
    return {"k": np.full((PAGE, 3), tag, np.int8)}


def _conserved(pt):
    """Page conservation: free + parked + live == usable, and no parked page
    appears in any active slot's table row."""
    live = int((pt.refcount[1:] > 0).sum())
    assert len(pt._free) + pt.cached_pages + live == pt.usable_pages, \
        (len(pt._free), pt.cached_pages, live, pt.usable_pages)
    mapped = {int(p) for s in range(pt.slots)
              for p in pt.table[s, : pt.held[s]]}
    assert not (mapped & set(pt._cached)), "parked page reachable by a slot"
    for p in pt._cached:
        assert pt.refcount[p] == 0


def _pt(num_pages=9, slots=2, width=4, **kw):
    return TieredPageTable(num_pages, PAGE, slots, width, **kw)


# -- PageStore -----------------------------------------------------------------

def test_store_host_roundtrip():
    s = PageStore(host_capacity=4)
    key = (8, 0xabc, b"chain")
    s.put(key, _img(7))
    img, tier = s.get(key)
    assert tier == "host"
    np.testing.assert_array_equal(img["k"], _img(7)["k"])
    assert s.get((8, 0xabc, b"other"))[0] is None
    assert s.stats["misses"] == 1


def test_store_lru_spills_to_disk(tmp_path):
    s = PageStore(host_capacity=2, disk_dir=tmp_path)
    keys = [(PAGE * (i + 1), i, bytes([i])) for i in range(3)]
    for i, k in enumerate(keys):
        s.put(k, _img(i))
    assert len(s) == 2 and s.stats["disk_writes"] == 1
    img, tier = s.get(keys[0])         # oldest was demoted
    assert tier == "disk"
    np.testing.assert_array_equal(img["k"], _img(0)["k"])
    assert (tmp_path / _slab_name(keys[0])).exists()


def test_store_overflow_without_disk_drops():
    s = PageStore(host_capacity=1)
    s.put((4, 1, b"a"), _img(1))
    s.put((4, 2, b"b"), _img(2))
    assert s.stats["dropped"] == 1
    assert s.get((4, 1, b"a")) == (None, None)


def test_store_flush_survives_restart(tmp_path):
    s = PageStore(host_capacity=8, disk_dir=tmp_path)
    key = (12, 0x5_5, b"\x01\x02")
    s.put(key, _img(3))
    s.flush()
    assert len(s) == 0
    s2 = PageStore(host_capacity=8, disk_dir=tmp_path)   # "restart"
    img, tier = s2.get(key)
    assert tier == "disk"
    np.testing.assert_array_equal(img["k"], _img(3)["k"])


@pytest.mark.parametrize("damage", ["flip", "truncate", "magic"])
def test_store_corrupt_slab_dropped_not_served(tmp_path, damage):
    """A torn/corrupted slab fails its CRC (or frame check) on read: it is
    unlinked and counted, never deserialized."""
    s = PageStore(host_capacity=1, disk_dir=tmp_path)
    key = (8, 0xdead, b"cc")
    s.put(key, _img(9))
    s.flush()
    path = tmp_path / _slab_name(key)
    raw = bytearray(path.read_bytes())
    if damage == "flip":
        raw[-1] ^= 0xFF
    elif damage == "truncate":
        raw = raw[: len(raw) // 2]
    else:
        raw[:4] = b"XXXX"
    path.write_bytes(bytes(raw))
    assert s.get(key) == (None, None)
    assert s.stats["corrupt_dropped"] == 1
    assert not path.exists()
    assert s.get(key) == (None, None)          # second probe: plain miss
    assert s.stats["corrupt_dropped"] == 1


def test_store_chain_collision_is_benign_miss(tmp_path):
    """Same filename, intact checksum, different chain bytes: a miss, not
    corruption — the verbatim chain comparison is the real gate, the hashed
    filename only a prefilter."""
    s = PageStore(host_capacity=1, disk_dir=tmp_path)
    key_a = (8, 0xf00, b"aaaa")
    key_b = (8, 0xf00, b"bbbb")
    s.put(key_a, _img(1))
    s.flush()
    os.rename(tmp_path / _slab_name(key_a), tmp_path / _slab_name(key_b))
    assert s.get(key_b) == (None, None)
    assert s.stats["corrupt_dropped"] == 0
    assert (tmp_path / _slab_name(key_b)).exists()


# -- TieredPageTable: device tier ----------------------------------------------

def test_retire_parks_indexed_pages():
    pt = _pt()
    keys = prefix_keys(_toks(8), PAGE)
    pt.admit_shared(0, 8, keys)
    _conserved(pt)
    pt.retire(0)
    assert pt.cached_pages == 2                 # parked, not freed...
    assert pt.free_pages == pt.usable_pages     # ...but still counted free
    assert all(p is not None for p in pt.lookup_keys(keys))
    _conserved(pt)


def test_parked_rehit_is_free_and_exact():
    pt = _pt()
    keys = prefix_keys(_toks(8), PAGE)
    first, _ = pt.admit_shared(0, 8, keys)
    pt.retire(0)
    pages, shared = pt.admit_shared(1, 8, keys)
    assert shared.all()
    assert list(pages) == list(first)           # the very same pages
    assert pt.tier_stats["device_hits"] == 2
    assert pt.cached_pages == 0
    _conserved(pt)


def test_unindexed_pages_still_free_normally():
    """Private pages (plain admit, decode-extend growth) never park."""
    pt = _pt()
    pt.admit(0, 8)
    pt.extend(0, 12)
    pt.retire(0)
    assert pt.cached_pages == 0
    assert len(pt._free) == pt.usable_pages
    _conserved(pt)


def test_allocation_pressure_evicts_lru_parked():
    pt = _pt(num_pages=5, slots=2)             # 4 usable pages
    keys = prefix_keys(_toks(8), PAGE)
    pt.admit_shared(0, 8, keys)
    pt.retire(0)                               # 2 parked, 2 free
    pt.admit(1, 12)                            # needs 3: evicts 1 parked
    assert pt.tier_stats["evictions"] == 1
    assert pt.cached_pages == 1
    _conserved(pt)
    # the surviving parked page is the root (children parked before parents
    # -> parents are LRU-newer); its index entry must still be reachable
    assert pt.lookup_keys(keys)[0] is not None


def test_watermark_bounds_parked_set():
    pt = _pt(num_pages=17, slots=2, width=8, watermark=2)
    keys = prefix_keys(_toks(20), PAGE)
    pt.admit_shared(0, 20, keys)
    pt.retire(0)
    assert pt.cached_pages == 2
    assert pt.tier_stats["evictions"] == 3
    _conserved(pt)


def test_admission_never_evicts_its_own_hits():
    """An admission whose misses force eviction must not evict the parked
    pages the SAME admission is about to map (they are pinned)."""
    pt = _pt(num_pages=4, slots=2, width=3)    # 3 usable pages
    a = _toks(8, seed=1)
    keys_a = prefix_keys(a, PAGE)
    pt.admit_shared(0, 8, keys_a)
    pt.retire(0)                               # 2 parked, 1 free
    b = np.concatenate([a[:4], _toks(8, seed=2)]).astype(np.int32)
    keys_b = prefix_keys(b, PAGE)              # hit page 0 of A, 2 misses
    pages, shared = pt.admit_shared(1, 12, keys_b)
    assert shared[0] and not shared[1] and not shared[2]
    assert pt.tier_stats["device_hits"] == 1
    assert pt.tier_stats["evictions"] == 1     # A's tail went, A's root didn't
    _conserved(pt)


def test_exhausted_pool_with_all_pages_pinned_raises():
    pt = _pt(num_pages=3, slots=2, width=2)    # 2 usable pages
    keys = prefix_keys(_toks(8), PAGE)
    pt.admit_shared(0, 8, keys)
    pt.retire(0)                               # both pages parked
    longer = np.concatenate([_toks(8), _toks(4, seed=9)]).astype(np.int32)
    with pytest.raises(RuntimeError, match="exhausted"):
        pt.admit_shared(1, 12, prefix_keys(longer, PAGE))
    _conserved(pt)                             # failed admission leaks nothing


def test_free_pages_for_nets_out_parked_hits():
    pt = _pt(num_pages=5)
    keys = prefix_keys(_toks(8), PAGE)
    pt.admit_shared(0, 8, keys)
    pt.retire(0)
    assert pt.free_pages == 4
    assert pt.free_pages_for(keys) == 2        # the 2 parked hits aren't supply
    assert pt.free_pages_for(prefix_keys(_toks(8, seed=3), PAGE)) == 4


# -- demote / promote ----------------------------------------------------------

def _tiered_with_store(tmp_path, num_pages=5, ns=b"m"):
    store = PageStore(host_capacity=1, disk_dir=tmp_path)
    pt = _pt(num_pages=num_pages, store=store)
    pt._current_ns = ns
    pt.register_demoter(ns, lambda pid: _img(pid))
    return pt, store


def test_eviction_demotes_bytes_under_chain_key(tmp_path):
    pt, store = _tiered_with_store(tmp_path)
    toks = _toks(8)
    keys = prefix_keys(toks, PAGE, namespace=b"m")
    pages, _ = pt.admit_shared(0, 8, keys)
    pt.retire(0)
    pt.flush_cached()
    assert pt.tier_stats["demotions"] == 2
    assert pt.cached_pages == 0 and len(pt._free) == pt.usable_pages
    for (sk, pid) in zip(_store_keys(keys), pages):
        img, tier = store.get(sk)
        assert tier in ("host", "disk")
        np.testing.assert_array_equal(img["k"], _img(int(pid))["k"])
    _conserved(pt)


def test_adopt_promotes_back_to_parked_and_rehits(tmp_path):
    pt, store = _tiered_with_store(tmp_path)
    toks = _toks(8)
    keys = prefix_keys(toks, PAGE, namespace=b"m")
    pt.admit_shared(0, 8, keys)
    pt.retire(0)
    pt.flush_cached()
    assert all(p is None for p in pt.lookup_keys(keys))
    # promotion walk: adopt each store hit in chain order, as the server does
    parent = -1
    for key, sk in zip(keys, _store_keys(keys)):
        img, tier = store.get(sk)
        assert img is not None
        page = pt.adopt(parent, key, sk[2], b"m")
        parent = page
    assert pt.tier_stats["promotions"] == 2
    _conserved(pt)
    pages, shared = pt.admit_shared(0, 8, keys)
    assert shared.all()
    _conserved(pt)


def test_restart_roundtrip_through_disk(tmp_path):
    """Process 1 demotes to disk; a brand-new table + store over the same
    directory promotes the same prefix — physical page ids differ, content
    keys (and thus bytes) match."""
    pt1, store1 = _tiered_with_store(tmp_path)
    keys = prefix_keys(_toks(8), PAGE, namespace=b"m")
    pages1, _ = pt1.admit_shared(0, 8, keys)
    pt1.retire(0)
    pt1.flush_cached()
    store1.flush()

    store2 = PageStore(host_capacity=4, disk_dir=tmp_path)
    pt2 = _pt(num_pages=5, store=store2)
    parent = -1
    for key, sk in zip(keys, _store_keys(keys)):
        img, tier = store2.get(sk)
        assert tier == "disk"
        np.testing.assert_array_equal(
            img["k"], _img(int(pages1[list(keys).index(key)]))["k"])
        parent = pt2.adopt(parent, key, sk[2], b"m")
    _, shared = pt2.admit_shared(0, 8, keys)
    assert shared.all()
    _conserved(pt2)


def test_namespaces_never_alias(tmp_path):
    """Two tenants with identical token streams get disjoint keys, index
    entries, and store slabs."""
    toks = _toks(8)
    ka = prefix_keys(toks, PAGE, namespace=b"A")
    kb = prefix_keys(toks, PAGE, namespace=b"B")
    assert [k[1] for k in ka] != [k[1] for k in kb]      # hashes differ
    assert all(a[2] != b[2] for a, b in zip(ka, kb))     # bytes differ
    store = PageStore(host_capacity=8, disk_dir=tmp_path)
    pt = _pt(num_pages=9, store=store)
    pt._current_ns = b"A"
    pt.register_demoter(b"A", lambda pid: _img(pid))
    pt.admit_shared(0, 8, ka)
    _, shared = pt.admit_shared(1, 8, kb)               # other tenant: miss
    assert not shared.any()
    pt.retire(0)
    pt.retire(1)
    _conserved(pt)


# -- property: random trace keeps every invariant ------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_random_trace_conserves_pages(seed):
    """Random admit/retire/extend/fork/evict traffic over a tight pool with
    tiering + store: page conservation and parked-page isolation hold after
    every single transition, and the pool drains clean at the end."""
    rng = np.random.default_rng(seed)
    store = PageStore(host_capacity=2, disk_dir=None)
    pt = _pt(num_pages=8, slots=3, width=4, store=store,
             watermark=int(rng.integers(0, 4)))
    pt.register_demoter(b"", lambda pid: _img(pid))
    prompts = [_toks(int(n), seed=int(rng.integers(5)))
               for n in rng.integers(1, 13, size=4)]
    busy: dict[int, int] = {}                  # slot -> tokens covered
    for _ in range(60):
        op = rng.integers(4)
        if op == 0 and len(busy) < pt.slots:
            slot = next(s for s in range(pt.slots) if s not in busy)
            p = prompts[int(rng.integers(len(prompts)))]
            try:
                pt.admit_shared(slot, len(p), prefix_keys(p, PAGE))
                busy[slot] = len(p)
            except RuntimeError:
                pass                           # pool genuinely full
        elif op == 1 and busy:
            slot = list(busy)[int(rng.integers(len(busy)))]
            pt.retire(slot)
            del busy[slot]
        elif op == 2 and busy:
            slot = list(busy)[int(rng.integers(len(busy)))]
            want = busy[slot] + int(rng.integers(1, 4))
            if want <= pt.max_pages * PAGE:
                try:
                    pt.extend(slot, want)
                    busy[slot] = want
                except RuntimeError:
                    pass
        elif op == 3 and busy:
            slot = list(busy)[int(rng.integers(len(busy)))]
            pt.fork_cow(slot, int(rng.integers(busy[slot])))
        _conserved(pt)
    for slot in list(busy):
        pt.retire(slot)
        _conserved(pt)
    pt.flush_cached()
    _conserved(pt)
    assert len(pt._free) == pt.usable_pages    # everything returned
