"""ByteTokenizer edge cases: the text <-> ids bijection the serving path
relies on (EOS retirement, prompt encoding, decode printing) at its
boundaries — empty prompt, all-special streams, and the full byte range
inside a reduced 512-vocab model.
"""
import numpy as np
import pytest

from repro.data.tokenizer import ByteTokenizer


def test_empty_prompt():
    tk = ByteTokenizer()
    ids = tk.encode("", bos=False)
    assert ids.shape == (0,) and ids.dtype == np.int32
    assert tk.decode(ids) == ""
    # with BOS the empty prompt is still a servable 1-token prompt
    ids = tk.encode("")
    assert ids.tolist() == [ByteTokenizer.BOS]
    assert tk.decode(ids) == ""


def test_all_special_token_stream_decodes_empty():
    tk = ByteTokenizer()
    stream = [ByteTokenizer.BOS, ByteTokenizer.EOS, ByteTokenizer.PAD,
              ByteTokenizer.PAD]
    assert tk.decode(np.asarray(stream, np.int32)) == ""
    # out-of-range ids (a sampler emitting into the 259..511 reduced-vocab
    # tail, or negative garbage) are stripped too, never crash decode
    assert tk.decode([300, 511, -1, 65]) == "A"


def test_bos_eos_framing():
    tk = ByteTokenizer()
    ids = tk.encode("hi", eos=True)
    assert ids[0] == ByteTokenizer.BOS and ids[-1] == ByteTokenizer.EOS
    assert ids[1:-1].tolist() == list(b"hi")
    assert tk.decode(ids) == "hi"


def test_round_trip_full_byte_range_within_512_vocab():
    """Every byte value round-trips exactly, and every emitted id fits the
    reduced() vocab of 512 — the boundary the serve smokes run at."""
    tk = ByteTokenizer(vocab=512)
    text = "".join(chr(i) for i in range(256)) + " déjà-vu ∞"
    ids = tk.encode(text, eos=True)
    assert int(ids.max()) <= 258 < 512
    assert int(ids.min()) >= 0
    assert tk.decode(ids) == text


def test_vocab_too_small_rejected():
    with pytest.raises(ValueError, match="cannot hold"):
        ByteTokenizer(vocab=ByteTokenizer.vocab_size - 1)
    ByteTokenizer(vocab=ByteTokenizer.vocab_size)   # exact fit is fine


def test_round_trip_arbitrary_unicode():
    tk = ByteTokenizer()
    for text in ("", "plain ascii", "emoji 🙂🙃", "mixed ©®µ¶ text\n\ttabs"):
        assert tk.decode(tk.encode(text)) == text
