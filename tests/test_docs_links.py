"""Docs link check: every relative markdown link in README.md and docs/*.md
must point at a file that exists in the repo. External (http/https/mailto)
targets are out of scope; fragment-only links (#section) are checked against
the file's own headings. This is the CI gate that keeps the docs map honest
as files move."""
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_DOCS = sorted(
    [os.path.join(REPO, "README.md")]
    + [os.path.join(REPO, "docs", f)
       for f in os.listdir(os.path.join(REPO, "docs")) if f.endswith(".md")]
)

# [text](target) — excluding images is unnecessary (image paths must exist
# too); nested brackets in link text don't occur in these docs
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug (enough of it for these docs)."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        return {_anchor(m.group(1)) for m in _HEADING.finditer(f.read())}


@pytest.mark.parametrize("doc", _DOCS, ids=[os.path.relpath(d, REPO) for d in _DOCS])
def test_no_dead_relative_links(doc):
    with open(doc, encoding="utf-8") as f:
        text = f.read()
    dead = []
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, frag = target.partition("#")
        if not path:                       # same-file #fragment
            if _anchor(frag) not in _anchors(doc):
                dead.append(target + " (no such heading)")
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(doc), path))
        if not os.path.exists(resolved):
            dead.append(target)
        elif frag and path.endswith(".md") \
                and _anchor(frag) not in _anchors(resolved):
            dead.append(target + " (no such heading)")
    assert not dead, f"dead links in {os.path.relpath(doc, REPO)}: {dead}"


def test_docs_inventory_nonempty():
    """The parametrized sweep silently passes on an empty list; pin the
    inventory so a bad glob can't turn the gate off."""
    names = {os.path.basename(d) for d in _DOCS}
    assert {"README.md", "SERVING.md", "DISPATCH.md", "MOE.md"} <= names
