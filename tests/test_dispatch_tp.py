"""Property test: tensor-parallel qgemm == unsharded qgemm, bit for bit.

The contract under test (kernels/dispatch.py, TP section):
  * row-parallel — K-sharded packed weights, replicated full-K activation
    prep, per-shard integer partial dots, ONE int32 psum BEFORE the requant
    epilogue — must match the unsharded path exactly for every registered
    cell, including bias and the expert axis. Integer psum is associative,
    prep/requant are shared verbatim, so equality is exact, not approximate.
  * column-parallel — N-sharded weights, no collective — exact per slice.
  * non-dividing shapes (e.g. a packed K whose word count doesn't split —
    32-operand bit-plane words AND 8-nibble s4 words, via cell.k_quantum)
    and narrow-accumulator (weight-only) row cells must FALL BACK to the
    replicated path rather than shard mid-word / psum in bf16 — the property
    holds trivially there, which is exactly the point: tp_plan may never
    choose an inexact plan.

The sweep is registry-driven (sorted(dispatch.cells())), so the mixed
w-ternary×a-int8 and int4 cells are covered automatically, keyed by
OperatingPoint.

Hypothesis (or the deterministic fallback shim) draws the operating point,
bias/expert/TP-degree/K/M/backend configuration; the whole property runs in
a subprocess with --xla_force_host_platform_device_count=8 (the flag cannot
be set once jax is initialized in the main pytest process).
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8
from _hypothesis_compat import given, settings, st
from repro.core import qlinear
from repro.core.precision import LayerQuant
from repro.core.quantize import QuantSpec
from repro.kernels import dispatch
from repro.kernels.dispatch import OperatingPoint

CELLS = sorted(dispatch.cells())
MESHES = {ns: jax.make_mesh((8 // ns, ns), ("data", "model")) for ns in (2, 4)}
checked = [0]
sharded_plans = [0]


def build(wprec, aprec, bias, experts, k, parallel, seed=0):
    spec = qlinear.QLinearSpec(
        k, 32, LayerQuant(QuantSpec(wprec), QuantSpec(aprec)),
        use_bias=bias, experts=experts, parallel=parallel)
    p = qlinear.init(jax.random.PRNGKey(seed), spec)
    if bias:
        p["b"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                   p["b"].shape) * 0.1
    return spec, qlinear.pack_params(p, spec)


@settings(max_examples=24, deadline=None)
@given(st.sampled_from(CELLS), st.booleans(), st.sampled_from([0, 2]),
       st.sampled_from([2, 4]), st.sampled_from([64, 96, 128]),
       st.sampled_from(["jnp", "pallas"]), st.integers(1, 9))
def row_parallel_matches_unsharded(cellkey, bias, experts, ns, k, backend, m):
    wprec, aprec, impl = cellkey
    impl_arg = "popcount" if impl == "*" else impl
    spec, p = build(wprec, aprec, bias, experts, k, "row")
    op = OperatingPoint.for_spec(spec, impl=impl_arg, backend=backend)
    shape = (experts, m, k) if experts else (m, k)
    x = jax.random.normal(jax.random.PRNGKey(m), shape) * 0.2
    ref = dispatch.qgemm(p, x, spec, op)
    tp = dispatch.TPSpec(MESHES[ns])
    cell = dispatch.lookup(op)
    plan = dispatch.tp_plan(cell, spec, "row", tp)
    # the plan is only allowed when it can be exact: wide cells, whole
    # packed storage units (cell.k_quantum: 32-bit-plane words, s4 nibble
    # words, int8 elements) per shard
    if plan == "row":
        assert cell.wide
        assert k % (cell.k_quantum * ns) == 0
        sharded_plans[0] += 1
    y = dispatch.qgemm(p, x, spec, op, tp=tp, parallel="row")
    assert y.shape == ref.shape and y.dtype == ref.dtype
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(ref, np.float32),
        err_msg=str((cellkey, bias, experts, ns, k, backend, m, plan)))
    checked[0] += 1


row_parallel_matches_unsharded()
assert checked[0] >= 24, checked
assert sharded_plans[0] > 0, "property never exercised a sharded row plan"

# column-parallel sweep (bit-exact, no collective) — every cell once
for (wprec, aprec, impl) in CELLS:
    impl_arg = "popcount" if impl == "*" else impl
    for experts in (0, 3):
        spec, p = build(wprec, aprec, True, experts, 64, "column")
        op = OperatingPoint.for_spec(spec, impl=impl_arg)
        shape = (experts, 5, 64) if experts else (5, 64)
        x = jax.random.normal(jax.random.PRNGKey(9), shape) * 0.2
        ref = dispatch.qgemm(p, x, spec, op)
        y = dispatch.qgemm(p, x, spec, op,
                           tp=dispatch.TPSpec(MESHES[4]), parallel="column")
        np.testing.assert_array_equal(np.asarray(y, np.float32),
                                      np.asarray(ref, np.float32),
                                      err_msg=str((wprec, aprec, impl, experts)))

print("DISPATCH_TP_OK", checked[0], sharded_plans[0])
'''


def test_row_parallel_qgemm_property():
    env = {**os.environ,
           "PYTHONPATH": os.pathsep.join(
               [os.path.join(REPO, "src"), os.path.join(REPO, "tests")])}
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "DISPATCH_TP_OK" in r.stdout, r.stdout[-2000:]
