"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import registry, transformer
from repro.models.common import ModelCtx, TRAIN

SERVE = ModelCtx(mode="serve")


@pytest.fixture(scope="module")
def built():
    """Init each reduced arch once per module."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            sp = transformer.build_specs(cfg)
            params = transformer.init(jax.random.PRNGKey(0), cfg)
            cache[name] = (cfg, sp, params)
        return cache[name]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch, built):
    cfg, sp, params = built(arch)
    b, t = 2, 32
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg, b, t)
    logits, aux, prefix = transformer.forward(
        params, batch["tokens"], sp, TRAIN, frontend_embeds=batch.get("frontend"))
    exp_t = t + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    assert logits.shape == (b, exp_t, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch, built):
    cfg, sp, params = built(arch)
    batch = registry.make_batch(jax.random.PRNGKey(2), cfg, 2, 16)
    (loss, _), grads = jax.value_and_grad(transformer.loss_fn, has_aux=True)(
        params, batch, sp, TRAIN)
    assert np.isfinite(float(loss)), arch
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), arch
    # at least one nonzero grad per block group
    total = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert total > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, built):
    """decode(prefill(prompt)) logits == forward(prompt+token) logits.

    Run in f32 so the check verifies the *algebra* (cache layout, ring
    buffers, recurrent state handoff) — in bf16 the two equivalent attention
    formulations accumulate ~1e-2 noise per layer which is not a bug.
    """
    cfg, sp, params = built(arch)
    f32 = ModelCtx(mode="train", dtype=jnp.float32)
    b, t = 2, 16
    batch = registry.make_batch(jax.random.PRNGKey(3), cfg, b, t + 1)
    tokens = batch["tokens"]
    fe = batch.get("frontend")

    logits_all, _, prefix = transformer.forward(params, tokens, sp, f32,
                                                frontend_embeds=fe)
    xlen = t + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    lp, cache = transformer.prefill(params, tokens[:, :t], sp, f32,
                                    frontend_embeds=fe, cache_len=xlen + 4)
    ld, _ = transformer.decode_step(params, cache, tokens[:, t:t + 1],
                                    jnp.int32(xlen), sp, f32)
    want = np.asarray(logits_all[:, prefix + t], np.float64)
    got = np.asarray(ld[:, 0], np.float64)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "phi3.5-moe-42b-a6.6b"])
def test_moe_router_topk_shapes(arch, built):
    """Router/top-k geometry on the reduced MoE archs: the router spec is a
    (D -> E) linear, each token lands exactly top_k assignments, and the aux
    counters account for every one (kept + dropped == B*S*top_k)."""
    from repro.core.precision import get_policy
    from repro.models import moe

    cfg, sp, params = built(arch)
    pol = get_policy(cfg.policy)
    specs = moe.moe_specs(cfg, pol)
    assert specs.router.in_dim == cfg.d_model
    assert specs.router.out_dim == cfg.n_experts
    assert 0 < specs.top_k <= specs.n_experts

    p = moe.moe_init(jax.random.PRNGKey(5), specs)
    b, s = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(6), (b, s, cfg.d_model),
                          jnp.float32)
    y, aux = moe.moe_apply(p, x, specs, ModelCtx(mode="train",
                                                 dtype=jnp.float32))
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y))), arch
    et = np.asarray(aux["expert_tokens"])
    assert et.shape == (cfg.n_experts,) and et.dtype == np.int32
    assert int(et.sum()) + int(aux["dropped"]) == b * s * specs.top_k
    assert np.isfinite(float(aux["loss"]))


def test_moe_shared_expert_path(built):
    """deepseek's always-on shared expert really contributes: its reduced
    config keeps one shared expert (params carry a 'shared' FFN whose spec
    widens d_ff by n_shared), and zeroing that FFN changes the block output.
    phi3.5 has no shared expert — no 'shared' leaf, same top-level keys
    otherwise."""
    from repro.core.precision import get_policy
    from repro.models import moe

    cfg, _, _ = built("deepseek-moe-16b")
    assert cfg.n_shared_experts == 1
    pol = get_policy(cfg.policy)
    specs = moe.moe_specs(cfg, pol)
    assert specs.shared is not None
    p = moe.moe_init(jax.random.PRNGKey(7), specs)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 8, cfg.d_model),
                          jnp.float32)
    ctx = ModelCtx(mode="train", dtype=jnp.float32)
    y, _ = moe.moe_apply(p, x, specs, ctx)
    p0 = dict(p, shared=jax.tree.map(jnp.zeros_like, p["shared"]))
    y0, _ = moe.moe_apply(p0, x, specs, ctx)
    assert bool(jnp.any(y != y0))

    cfg_phi, _, _ = built("phi3.5-moe-42b-a6.6b")
    specs_phi = moe.moe_specs(cfg_phi, get_policy(cfg_phi.policy))
    assert specs_phi.shared is None
    p_phi = moe.moe_init(jax.random.PRNGKey(9), specs_phi)
    assert "shared" not in p_phi
    assert set(p_phi) == set(p) - {"shared"}


@pytest.mark.parametrize("arch", ["llama3.2-3b", "xlstm-125m", "recurrentgemma-9b",
                                  "deepseek-moe-16b", "phi3.5-moe-42b-a6.6b"])
def test_serve_packed_forward(arch, built):
    """pack_for_serve params run the serve path without NaNs."""
    cfg, sp, params = built(arch)
    sparams = transformer.pack_for_serve(params, cfg)
    b, t = 2, 16
    batch = registry.make_batch(jax.random.PRNGKey(4), cfg, b, t)
    logits, cache = transformer.prefill(sparams, batch["tokens"], sp, SERVE,
                                        frontend_embeds=batch.get("frontend"))
    assert logits.shape == (b, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    ld, _ = transformer.decode_step(sparams, cache, batch["tokens"][:, :1],
                                    jnp.int32(t), sp, SERVE)
    assert not bool(jnp.any(jnp.isnan(ld))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_shapes_match_init_cache(arch, built):
    cfg, sp, params = built(arch)
    shapes = transformer.cache_shapes(cfg, 2, 32)
    cache = transformer.init_cache(cfg, 2, 32)
    flat_s = jax.tree.leaves(shapes)
    flat_c = jax.tree.leaves(cache)
    assert len(flat_s) == len(flat_c)
    for s, c in zip(flat_s, flat_c):
        assert s.shape == c.shape and s.dtype == c.dtype


def test_full_config_param_counts():
    """Analytic N roughly matches the published sizes (sanity of configs)."""
    approx = {"nemotron-4-340b": 340e9, "qwen1.5-32b": 32e9, "llama3.2-3b": 3.2e9,
              "gemma3-4b": 4e9, "phi-3-vision-4.2b": 4e9,
              "phi3.5-moe-42b-a6.6b": 42e9, "deepseek-moe-16b": 16e9,
              "whisper-tiny": 37e6, "xlstm-125m": 125e6, "recurrentgemma-9b": 9e9}
    for arch, want in approx.items():
        n = get_config(arch).n_params()
        assert 0.4 * want < n < 2.1 * want, (arch, n, want)
