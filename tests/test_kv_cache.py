"""PageTable invariants under random admit/extend/retire traces — now with
prefix sharing (refcounted hash-indexed pages), copy-on-write forks, and
preemption swap in/out.

The page pool is the correctness foundation of the paged serving path: a
refcount that drifts from the table silently cross-contaminates or leaks
pages, a stale share-index entry hands a freed page to a new request, a CoW
fork that drops the source's bytes corrupts every co-owner, and a coverage
mismatch (pages != tokens) makes the decode write index run off the slot's
page list. Property-test all of it with random traces (hypothesis, or the
deterministic fallback shim).
"""
import random

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.launch import kv_cache
from repro.launch.kv_cache import (NULL_PAGE, PageTable, pages_for,
                                   prefix_keys)


def _check_invariants(pt: PageTable, model: dict):
    owned = [int(p) for s in range(pt.slots) for p in pt.table[s, : pt.held[s]]]
    distinct = set(owned)
    # the scratch page is never handed out
    assert NULL_PAGE not in distinct
    # refcount == number of (slot, index) table mappings, for every page —
    # in particular a page is mapped by at most one slot unless it is shared
    counts: dict[int, int] = {}
    for p in owned:
        counts[p] = counts.get(p, 0) + 1
    for p in range(pt.num_pages):
        assert int(pt.refcount[p]) == counts.get(p, 0), \
            (p, counts.get(p, 0), int(pt.refcount[p]))
    # free + distinct-owned == pool (minus the reserved scratch page):
    # a page is freed exactly when its refcount hits zero
    assert pt.free_pages + len(distinct) == pt.num_pages - 1
    assert distinct.isdisjoint(pt._free)
    # stats() reports occupancy over USABLE pages: page 0 scratch is not
    # demand, live == usable - free == distinct owned, occupancy in [0, 1]
    st = pt.stats()
    assert st["usable_pages"] == pt.num_pages - 1
    assert st["free_pages"] == pt.free_pages
    assert st["live_pages"] == st["usable_pages"] - st["free_pages"] \
        == len(distinct)
    assert st["occupancy"] == pytest.approx(
        len(distinct) / st["usable_pages"])
    assert 0.0 <= st["occupancy"] <= 1.0
    # the share index only ever points at live pages, bijectively
    for key, p in pt._index.items():
        assert int(pt.refcount[p]) >= 1, (key, p)
        assert pt._page_key[p] == key
    assert len(pt._page_key) == len(pt._index)
    for s in range(pt.slots):
        if pt.active[s]:
            # per-slot pages cover exactly the slot's tokens (pos + 1)
            assert int(pt.tokens[s]) == model[s]
            assert int(pt.held[s]) == pages_for(model[s], pt.page_size)
        else:
            assert s not in model
            assert int(pt.held[s]) == 0 and int(pt.tokens[s]) == 0
        # table entries beyond the held count all point at scratch
        assert (pt.table[s, pt.held[s]:] == NULL_PAGE).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_random_traces_maintain_invariants(seed):
    rng = random.Random(seed)
    page_size = rng.choice([1, 2, 4, 8])
    slots = rng.randint(1, 5)
    max_pages = rng.randint(1, 8)
    # sometimes oversubscribed (pool < slots * max_pages), sometimes ample
    num_pages = rng.randint(2, slots * max_pages + 3)
    pt = PageTable(num_pages, page_size, slots, max_pages)
    cap = max_pages * page_size
    model: dict[int, int] = {}

    for _ in range(60):
        s = rng.randrange(slots)
        op = rng.random()
        if not pt.active[s] and op < 0.55:
            n = rng.randint(1, cap)
            if pt.can_admit(n):
                ids = pt.admit(s, n)
                assert len(ids) == pages_for(n, page_size)
                assert NULL_PAGE not in ids
                model[s] = n
            else:
                with pytest.raises(RuntimeError):
                    pt.admit(s, n)
        elif pt.active[s] and op < 0.75:
            n = rng.randint(1, cap)
            need = pages_for(n, page_size) - int(pt.held[s])
            if n <= model[s]:
                assert pt.extend(s, n) == []          # no-op growth
            elif need <= pt.free_pages:
                got = pt.extend(s, n)
                assert len(got) == max(need, 0)
                model[s] = n
            else:
                with pytest.raises(RuntimeError):
                    pt.extend(s, n)
        elif pt.active[s]:
            held = int(pt.held[s])
            free_before = pt.free_pages
            freed = pt.retire(s)
            # retire returns all pages to the pool
            assert len(freed) == held
            assert pt.free_pages == free_before + held
            model.pop(s)
        _check_invariants(pt, model)


def test_admit_rejects_bad_sizes():
    pt = PageTable(9, 4, 2, 2)
    with pytest.raises(ValueError):
        pt.admit(0, 0)
    with pytest.raises(ValueError):
        pt.admit(0, 9)      # > max_pages * page_size
    pt.admit(0, 5)
    with pytest.raises(RuntimeError):
        pt.admit(0, 1)      # already active
    with pytest.raises(ValueError):
        pt.extend(0, 9)
    with pytest.raises(RuntimeError):
        pt.extend(1, 1)     # not active
    with pytest.raises(RuntimeError):
        pt.retire(1)


def test_pool_device_sharded_over_data_host_table_global():
    """Paged-KV + sharding interaction: the PageTable admit/extend/retire
    invariants are pure host-side bookkeeping and must hold unchanged when
    the page pool itself is device-put with a ("data",) sharding (the
    tensor-parallel server's per-data-shard pool layout) — and KV written
    through the table into the sharded pool must read back exactly.

    The host table stays global numpy throughout: device placement of the
    pool is invisible to the allocator. Runs on however many devices the
    process has (1 in the tier-1 suite; the 8-device TP suite exercises the
    genuinely-distributed case end to end in tests/test_serving_tp.py)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    page_size, slots, max_pages = 4, 3, 4
    num_pages = max(8, 4 * ndev)              # divides the data axis exactly
    pool = jnp.zeros((num_pages, page_size, 2, 8), jnp.float32)
    pool = jax.device_put(pool, NamedSharding(mesh, P("data")))
    assert pool.sharding.spec == P("data")

    pt = PageTable(num_pages, page_size, slots, max_pages)
    model: dict[int, int] = {}

    def write_tokens(slot, lo, hi):
        """Store a recognizable value per (slot, logical token) through the
        page table, exercising cross-shard page ids."""
        nonlocal pool
        for tok in range(lo, hi):
            pid = int(pt.table[slot, tok // page_size])
            val = float(slot * 1000 + tok + 1)
            pool = pool.at[pid, tok % page_size].set(val)

    ids = pt.admit(0, 6)
    model[0] = 6
    write_tokens(0, 0, 6)
    pt.admit(1, 3)
    model[1] = 3
    write_tokens(1, 0, 3)
    _check_invariants(pt, model)

    pt.extend(0, 11)                          # grows across a page boundary
    model[0] = 11
    write_tokens(0, 6, 11)
    _check_invariants(pt, model)

    # gather each slot's logical view back from the sharded pool: exact
    for slot, n in model.items():
        view = np.asarray(pool[pt.table[slot]]).reshape(-1, 2, 8)
        for tok in range(n):
            assert view[tok, 0, 0] == slot * 1000 + tok + 1, (slot, tok)

    freed = pt.retire(0)
    model.pop(0)
    assert len(freed) == pages_for(11, page_size)
    _check_invariants(pt, model)
    pt.retire(1)
    model.pop(1)
    _check_invariants(pt, model)
    assert pt.free_pages == pt.usable_pages
    # the table is host numpy, untouched by device placement
    assert isinstance(pt.table, np.ndarray)
    assert pool.sharding.spec == P("data")    # placement survived the writes


def _keys_for(pid: int, n: int, page_size: int) -> list:
    """Deterministic per-"prompt-stream" share keys: two admits with the same
    pid alias pages wherever their covered token counts line up — the same
    exact-coverage contract `prefix_keys` provides for real token prefixes."""
    ks, c = [], 0
    while c < n:
        c = min(c + page_size, n)
        ks.append((pid, c))
    return ks


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_shared_cow_swap_traces_maintain_invariants(seed):
    """Random traces over the FULL action set — shared admit (sometimes with
    deferred indexing + progressive `index_pages`, the chunked-prefill
    protocol), extend, CoW fork, swap out/in, retire — keep every allocator
    invariant: refcounts mirror the table, a page is freed iff its refcount
    hits zero, forks are private and unindexed, decode growth is never
    shared, swapped-in pages are fresh, deferred pages stay unindexed until
    their bytes are declared written, and the share index never points at a
    free page."""
    rng = random.Random(seed)
    page_size = rng.choice([1, 2, 4])
    slots = rng.randint(2, 5)
    max_pages = rng.randint(2, 6)
    num_pages = rng.randint(4, slots * max_pages + 4)
    pt = PageTable(num_pages, page_size, slots, max_pages)
    cap = max_pages * page_size
    model: dict[int, int] = {}
    swapped: list[int] = []         # token counts of swapped-out requests
    pending: dict[int, tuple] = {}  # slot -> (keys, n, covered) deferred

    for _ in range(80):
        s = rng.randrange(slots)
        op = rng.random()
        if not pt.active[s] and op < 0.35:
            n = rng.randint(1, cap)
            keys = _keys_for(rng.randrange(3), n, page_size)
            hits = pt.lookup_keys(keys)
            misses = sum(1 for h in hits if h is None)
            defer = rng.random() < 0.5
            if pt.free_pages >= misses:
                ids, shared = pt.admit_shared(s, n, keys, defer_index=defer)
                assert len(ids) == pages_for(n, page_size)
                assert int(shared.sum()) == len(hits) - misses
                for i, h in enumerate(hits):
                    if h is not None:      # every hit really aliased
                        assert int(ids[i]) == h and shared[i]
                    elif defer:            # misses unindexed until bytes land
                        assert int(ids[i]) not in pt._page_key
                if defer and misses:
                    fresh = {int(ids[i]) for i, h in enumerate(hits)
                             if h is None}
                    pending[s] = (keys, n, 0, fresh)
                model[s] = n
            else:
                with pytest.raises(RuntimeError):
                    pt.admit_shared(s, n, keys, defer_index=defer)
        elif pt.active[s] and s in pending and op < 0.5:
            # a prefill chunk landed: register the now-written leading pages
            keys, n, covered, fresh = pending[s]
            covered = min(n, covered + rng.randint(1, n))
            pt.index_pages(s, keys, covered)
            for i, key in enumerate(keys):
                pid = int(pt.table[s, i])
                # freshly-allocated pages whose bytes are not yet declared
                # written must stay out of the share index (a hit would hand
                # a co-owner garbage KV); hits were indexed all along
                if key[0] > covered and pid in fresh:
                    assert pid not in pt._page_key
            if covered >= n:
                pending.pop(s)
            else:
                pending[s] = (keys, n, covered, fresh)
        elif not pt.active[s] and swapped and op < 0.5:
            n = swapped[-1]
            if pt.can_admit(n):
                ids = pt.swap_in(s, n)
                swapped.pop()
                assert len(ids) == pages_for(n, page_size)
                for p in ids:              # private, fresh, unindexed
                    assert int(pt.refcount[p]) == 1
                    assert int(p) not in pt._page_key
                model[s] = n
        elif pt.active[s] and op < 0.62:
            n = rng.randint(1, cap)
            need = pages_for(n, page_size) - int(pt.held[s])
            if n <= model[s]:
                assert pt.extend(s, n) == []          # no-op growth
            elif need <= pt.free_pages:
                got = pt.extend(s, n)
                for p in got:              # decode growth is never shared
                    assert int(pt.refcount[p]) == 1
                    assert p not in pt._page_key
                model[s] = n
            else:
                with pytest.raises(RuntimeError):
                    pt.extend(s, n)
        elif pt.active[s] and op < 0.78:
            pos = rng.randrange(model[s])
            idx = pos // page_size
            before = int(pt.table[s, idx])
            rc = int(pt.refcount[before])
            assert pt.cow_pending(s, pos) == (rc > 1)
            if rc > 1 and pt.free_pages >= 1:
                src, dst = pt.fork_cow(s, pos)
                assert src == before and dst == int(pt.table[s, idx])
                assert int(pt.refcount[src]) == rc - 1   # co-owners keep it
                assert int(pt.refcount[dst]) == 1
                assert dst not in pt._page_key           # forks never indexed
            elif rc > 1:
                with pytest.raises(RuntimeError):        # dry pool, no state
                    pt.fork_cow(s, pos)                  # change before raise
                assert int(pt.table[s, idx]) == before
                assert int(pt.refcount[before]) == rc
            else:
                assert pt.fork_cow(s, pos) is None       # exclusive: in place
        elif pt.active[s] and op < 0.9:
            held = [int(p) for p in pt.slot_pages(s)]
            freed = pt.swap_out(s)
            # freed exactly the pages whose refcount hit zero
            assert set(freed) == {p for p in held if pt.refcount[p] == 0}
            swapped.append(model.pop(s))
            pending.pop(s, None)
        elif pt.active[s]:
            held = [int(p) for p in pt.slot_pages(s)]
            freed = pt.retire(s)
            assert set(freed) == {p for p in held if pt.refcount[p] == 0}
            model.pop(s)
            pending.pop(s, None)
        _check_invariants(pt, model)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fork_debt_formula_matches_realized_forks(seed):
    """The server's admission reservation (`Server._fork_debt`) prices CoW
    exposure per PHYSICAL page as min(#writers, refcount - 1): of the
    writers poised to dirty a shared page, the first rc-1 must fork (each
    fork drops one reference) and the last finds itself sole owner and
    writes in place. Property: that closed-form count equals the number of
    forks actually realized when every slot performs its pending write, in
    ANY order — so admission can reserve exactly, without double-counting
    aliased writers (the PR 8 `can_admit` fix)."""
    rng = random.Random(seed)
    page_size = rng.choice([1, 2, 4])
    slots = rng.randint(2, 6)
    max_pages = rng.randint(1, 4)
    # pool sized so admits and every predicted fork always fit
    num_pages = slots * (max_pages + 1) + 2
    pt = PageTable(num_pages, page_size, slots, max_pages)
    cap = max_pages * page_size
    model: dict[int, int] = {}
    for s in range(slots):
        n = rng.randint(1, cap)
        # two "prompt streams" only: heavy aliasing across slots
        pt.admit_shared(s, n, _keys_for(rng.randrange(2), n, page_size))
        model[s] = n
    # each slot is about to write one covered position (a decode write into
    # its current page, or a CoW-guarded rewrite) — possibly aliasing
    pos = {s: rng.randrange(model[s]) for s in model}
    writers: dict[int, int] = {}
    for s, p in pos.items():
        pid = int(pt.table[s, p // page_size])
        writers[pid] = writers.get(pid, 0) + 1
    predicted = sum(min(w, int(pt.refcount[pid]) - 1)
                    for pid, w in writers.items())
    # realize the writes in a random order and count actual forks
    order = list(pos)
    rng.shuffle(order)
    forks = 0
    for s in order:
        assert pt.cow_pending(s, pos[s]) == \
            (int(pt.refcount[int(pt.table[s, pos[s] // page_size])]) > 1)
        if pt.fork_cow(s, pos[s]) is not None:
            forks += 1
    assert forks == predicted
    _check_invariants(pt, model)


def test_prefix_keys_exact_coverage_contract():
    """Keys match iff the covered token prefixes are identical: equal
    prefixes agree page-for-page, a divergent tail (or a different length
    into the same page) changes that page's key, and full-page keys survive
    a longer prompt extending past them."""
    P = 4
    a = np.arange(10, dtype=np.int32)
    ka = prefix_keys(a, P)
    assert len(ka) == pages_for(10, P) == 3
    assert [k[0] for k in ka] == [4, 8, 10]          # covered token counts
    # same prefix, longer prompt: full pages agree, partial page differs
    b = np.arange(12, dtype=np.int32)
    kb = prefix_keys(b, P)
    assert kb[:2] == ka[:2] and kb[2] != ka[2]
    # divergent tail inside the last page changes only that key
    c = a.copy(); c[-1] += 1
    kc = prefix_keys(c, P)
    assert kc[:2] == ka[:2] and kc[2] != ka[2]
    # divergence inside the first page changes every key (rolling chain)
    d = a.copy(); d[0] += 1
    kd = prefix_keys(d, P)
    assert all(x != y for x, y in zip(kd, ka))
    # keys within one prompt are distinct (chained)
    assert len(set(ka)) == len(ka)


def test_can_admit_counts_reclaimable_pages():
    """The --preempt admission fix: pages held by preemptable running
    requests count toward admissibility (they can be swapped out), so a
    full pool no longer rejects work the scheduler could make room for."""
    pt = PageTable(9, 4, 2, 4)
    pt.admit(0, 16)                     # slot 0 holds 4 of 8 usable pages
    pt.admit(1, 16)                     # slot 1 holds the rest
    assert pt.free_pages == 0
    assert not pt.can_admit(8)
    assert pt.can_admit(8, reclaimable=int(pt.held[1]))
    assert not pt.can_admit(32, reclaimable=int(pt.held[1]))  # beyond pool


def test_cow_fork_preserves_bytes_and_swap_roundtrips():
    """Device-side halves of the scheduler: copy_page gives the forker a
    bit-exact copy while the source keeps serving its co-owner, and
    swap_out_slot -> swap_in_slot round-trips a slot's pages + slab row
    exactly (into a different slot and different physical pages)."""
    import jax.numpy as jnp
    P, slots = 4, 3
    pt = PageTable(12, P, slots, 4)
    cache = {"k": jnp.zeros((12, P, 2, 4), jnp.float32),
             "state": jnp.zeros((slots, 8), jnp.float32)}
    mask = {"k": True, "state": False}

    # slot 0 admits 6 tokens under share keys and writes recognizable bytes
    keys = _keys_for(7, 6, P)
    ids0, shared0 = pt.admit_shared(0, 6, keys)
    assert not shared0.any()
    for t in range(6):
        pid = int(pt.table[0, t // P])
        cache["k"] = cache["k"].at[pid, t % P].set(float(100 + t))
    cache["state"] = cache["state"].at[0].set(1.0)

    # slot 1 shares both pages (full + partial), then CoW-forks the partial
    ids1, shared1 = pt.admit_shared(1, 6, keys)
    assert shared1.all() and (ids1 == ids0).all()
    src, dst = pt.fork_cow(1, 5)
    cache = kv_cache.copy_page(cache, src, dst, mask)
    # the fork is bit-exact and the source is untouched
    assert (np.asarray(cache["k"][dst]) == np.asarray(cache["k"][src])).all()
    # writer diverges on its fork; the co-owner's page keeps its bytes
    cache["k"] = cache["k"].at[dst, 1].set(-5.0)
    assert float(cache["k"][src, 1, 0, 0]) == 105.0
    assert int(pt.refcount[src]) == 1 and int(pt.refcount[dst]) == 1

    # swap slot 0 out (gather BEFORE releasing), back in at a different slot
    ids = pt.slot_pages(0)
    saved = kv_cache.swap_out_slot(cache, 0, ids, mask)
    assert isinstance(saved["k"], np.ndarray)       # host-side slab
    pt.swap_out(0)
    new_ids = pt.swap_in(2, 6)
    cache = kv_cache.swap_in_slot(cache, saved, 2, new_ids, mask)
    for t in range(6):
        pid = int(pt.table[2, t // P])
        assert float(cache["k"][pid, t % P, 0, 0]) == 100 + t, t
    assert float(cache["state"][2, 0]) == 1.0
    _check_invariants(pt, {1: 6, 2: 6})


def test_lifo_reuse_and_full_cycle():
    pt = PageTable(5, 2, 2, 2)
    a = pt.admit(0, 4)
    assert pt.free_pages == 2
    freed = pt.retire(0)
    assert sorted(freed) == sorted(int(p) for p in a)
    b = pt.admit(1, 4)
    # LIFO free list: the just-freed pages come back first
    assert set(int(p) for p in b) == set(freed)
    assert pt.device_table().shape == (2, 2)
    assert (np.asarray(pt.device_table())[0] == NULL_PAGE).all()
