"""PageTable invariants under random admit/extend/retire traces.

The page pool is the correctness foundation of the paged serving path: a
double-owned page silently cross-contaminates two requests' KV, a leaked
page shrinks capacity forever, and a coverage mismatch (pages != tokens)
makes the decode write index run off the slot's page list. Property-test all
of it with random traces (hypothesis, or the deterministic fallback shim).
"""
import random

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.launch.kv_cache import NULL_PAGE, PageTable, pages_for


def _check_invariants(pt: PageTable, model: dict):
    owned = [int(p) for s in range(pt.slots) for p in pt.table[s, : pt.held[s]]]
    # the scratch page is never handed out
    assert NULL_PAGE not in owned
    # no page owned twice
    assert len(owned) == len(set(owned)), owned
    # free + used == pool (minus the reserved scratch page)
    assert pt.free_pages + len(owned) == pt.num_pages - 1
    for s in range(pt.slots):
        if pt.active[s]:
            # per-slot pages cover exactly the slot's tokens (pos + 1)
            assert int(pt.tokens[s]) == model[s]
            assert int(pt.held[s]) == pages_for(model[s], pt.page_size)
        else:
            assert s not in model
            assert int(pt.held[s]) == 0 and int(pt.tokens[s]) == 0
        # table entries beyond the held count all point at scratch
        assert (pt.table[s, pt.held[s]:] == NULL_PAGE).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_random_traces_maintain_invariants(seed):
    rng = random.Random(seed)
    page_size = rng.choice([1, 2, 4, 8])
    slots = rng.randint(1, 5)
    max_pages = rng.randint(1, 8)
    # sometimes oversubscribed (pool < slots * max_pages), sometimes ample
    num_pages = rng.randint(2, slots * max_pages + 3)
    pt = PageTable(num_pages, page_size, slots, max_pages)
    cap = max_pages * page_size
    model: dict[int, int] = {}

    for _ in range(60):
        s = rng.randrange(slots)
        op = rng.random()
        if not pt.active[s] and op < 0.55:
            n = rng.randint(1, cap)
            if pt.can_admit(n):
                ids = pt.admit(s, n)
                assert len(ids) == pages_for(n, page_size)
                assert NULL_PAGE not in ids
                model[s] = n
            else:
                with pytest.raises(RuntimeError):
                    pt.admit(s, n)
        elif pt.active[s] and op < 0.75:
            n = rng.randint(1, cap)
            need = pages_for(n, page_size) - int(pt.held[s])
            if n <= model[s]:
                assert pt.extend(s, n) == []          # no-op growth
            elif need <= pt.free_pages:
                got = pt.extend(s, n)
                assert len(got) == max(need, 0)
                model[s] = n
            else:
                with pytest.raises(RuntimeError):
                    pt.extend(s, n)
        elif pt.active[s]:
            held = int(pt.held[s])
            free_before = pt.free_pages
            freed = pt.retire(s)
            # retire returns all pages to the pool
            assert len(freed) == held
            assert pt.free_pages == free_before + held
            model.pop(s)
        _check_invariants(pt, model)


def test_admit_rejects_bad_sizes():
    pt = PageTable(9, 4, 2, 2)
    with pytest.raises(ValueError):
        pt.admit(0, 0)
    with pytest.raises(ValueError):
        pt.admit(0, 9)      # > max_pages * page_size
    pt.admit(0, 5)
    with pytest.raises(RuntimeError):
        pt.admit(0, 1)      # already active
    with pytest.raises(ValueError):
        pt.extend(0, 9)
    with pytest.raises(RuntimeError):
        pt.extend(1, 1)     # not active
    with pytest.raises(RuntimeError):
        pt.retire(1)


def test_pool_device_sharded_over_data_host_table_global():
    """Paged-KV + sharding interaction: the PageTable admit/extend/retire
    invariants are pure host-side bookkeeping and must hold unchanged when
    the page pool itself is device-put with a ("data",) sharding (the
    tensor-parallel server's per-data-shard pool layout) — and KV written
    through the table into the sharded pool must read back exactly.

    The host table stays global numpy throughout: device placement of the
    pool is invisible to the allocator. Runs on however many devices the
    process has (1 in the tier-1 suite; the 8-device TP suite exercises the
    genuinely-distributed case end to end in tests/test_serving_tp.py)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    page_size, slots, max_pages = 4, 3, 4
    num_pages = max(8, 4 * ndev)              # divides the data axis exactly
    pool = jnp.zeros((num_pages, page_size, 2, 8), jnp.float32)
    pool = jax.device_put(pool, NamedSharding(mesh, P("data")))
    assert pool.sharding.spec == P("data")

    pt = PageTable(num_pages, page_size, slots, max_pages)
    model: dict[int, int] = {}

    def write_tokens(slot, lo, hi):
        """Store a recognizable value per (slot, logical token) through the
        page table, exercising cross-shard page ids."""
        nonlocal pool
        for tok in range(lo, hi):
            pid = int(pt.table[slot, tok // page_size])
            val = float(slot * 1000 + tok + 1)
            pool = pool.at[pid, tok % page_size].set(val)

    ids = pt.admit(0, 6)
    model[0] = 6
    write_tokens(0, 0, 6)
    pt.admit(1, 3)
    model[1] = 3
    write_tokens(1, 0, 3)
    _check_invariants(pt, model)

    pt.extend(0, 11)                          # grows across a page boundary
    model[0] = 11
    write_tokens(0, 6, 11)
    _check_invariants(pt, model)

    # gather each slot's logical view back from the sharded pool: exact
    for slot, n in model.items():
        view = np.asarray(pool[pt.table[slot]]).reshape(-1, 2, 8)
        for tok in range(n):
            assert view[tok, 0, 0] == slot * 1000 + tok + 1, (slot, tok)

    freed = pt.retire(0)
    model.pop(0)
    assert len(freed) == pages_for(11, page_size)
    _check_invariants(pt, model)
    pt.retire(1)
    model.pop(1)
    _check_invariants(pt, model)
    assert pt.free_pages == pt.usable_pages
    # the table is host numpy, untouched by device placement
    assert isinstance(pt.table, np.ndarray)
    assert pool.sharding.spec == P("data")    # placement survived the writes


def test_lifo_reuse_and_full_cycle():
    pt = PageTable(5, 2, 2, 2)
    a = pt.admit(0, 4)
    assert pt.free_pages == 2
    freed = pt.retire(0)
    assert sorted(freed) == sorted(int(p) for p in a)
    b = pt.admit(1, 4)
    # LIFO free list: the just-freed pages come back first
    assert set(int(p) for p in b) == set(freed)
    assert pt.device_table().shape == (2, 2)
    assert (np.asarray(pt.device_table())[0] == NULL_PAGE).all()
