"""Tests for optimizer (incl. int8 states), gradient compression, data
pipeline determinism, checkpoint atomicity/retention/resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import ckpt
from repro.data.pipeline import PipelineConfig, SyntheticLM, make_source
from repro.optim import adamw as adamw_mod
from repro.optim import compress
from repro.optim.adamw import adamw, apply_updates, cosine_schedule


# -- optimizer ---------------------------------------------------------------

def _toy_problem():
    params = {"w": jnp.array([2.0, -3.0]), "b": jnp.zeros((2, 2))}
    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)
    return params, loss


@pytest.mark.parametrize("int8_state", [False, True])
def test_adamw_converges(int8_state):
    params, loss = _toy_problem()
    opt = adamw(1e-1, weight_decay=0.0, int8_state=int8_state)
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state, _ = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-2


def test_int8_state_memory_is_quarter():
    params = {"w": jnp.zeros((1024, 256))}
    opt8 = adamw(1e-3, int8_state=True)
    s8 = opt8.init(params)
    b8 = sum(np.asarray(x).nbytes for x in jax.tree.leaves(s8))
    opt32 = adamw(1e-3, int8_state=False)
    s32 = opt32.init(params)
    b32 = sum(np.asarray(x).nbytes for x in jax.tree.leaves(s32))
    assert b8 < 0.3 * b32


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


@given(st.integers(0, 2**31 - 1), st.sampled_from([(1000,), (16, 300), (4, 4, 64)]))
@settings(max_examples=12, deadline=None)
def test_q8_codec_roundtrip_error(seed, shape):
    """Property: shape-preserving int8 codec, error <= blockmax/254 elementwise."""
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 10
    codes, scale = adamw_mod._q8_encode(x)
    assert codes.shape == x.shape and codes.dtype == jnp.int8
    back = adamw_mod._q8_decode(codes, scale, x.shape, x.size)
    tol = float(jnp.max(jnp.abs(x))) / 127.0
    err = float(jnp.max(jnp.abs(back - x)))
    assert err <= tol * 0.51 + 1e-6


# -- gradient compression ------------------------------------------------------

def test_compressed_psum_matches_mean(tmp_path):
    """int8-compressed all-reduce ~= exact psum within quantization error."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.optim.compress import shard_map
    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(1,), ("d",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}

    def body(gg):
        return compress.compressed_psum(gg, "d", jax.random.PRNGKey(1))

    out = shard_map(body, mesh=mesh, in_specs=(P("d"),), out_specs=P("d"))(
        {"w": g["w"][None]})
    got = out["w"][0]
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(got - g["w"]))) <= 2.1 * scale


def test_quantize_grad_unbiased():
    g = jnp.full((2000,), 0.3)
    samples = []
    for i in range(32):
        codes, scale = compress.quantize_grad(g, jax.random.PRNGKey(i))
        samples.append(np.asarray(codes, np.float32) * float(scale))
    mean = np.mean(samples)
    assert abs(mean - 0.3) < 2e-3


# -- data pipeline ---------------------------------------------------------------

def test_pipeline_deterministic_and_sharded():
    cfg = PipelineConfig(vocab=1000, seq_len=64, global_batch=8)
    src = SyntheticLM(cfg)
    b1, b2 = src.batch_at(7), src.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 64)
    assert (b1["tokens"] != src.batch_at(8)["tokens"]).any()
    # host sharding partitions the global batch
    h0 = SyntheticLM(PipelineConfig(1000, 64, 8, host_index=0, host_count=2))
    h1 = SyntheticLM(PipelineConfig(1000, 64, 8, host_index=1, host_count=2))
    assert h0.batch_at(0)["tokens"].shape == (4, 64)
    assert h1.batch_at(0)["tokens"].shape == (4, 64)


def test_pipeline_targets_shifted():
    cfg = PipelineConfig(vocab=100, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch_at(0)
    # targets[t] is tokens[t+1] of the underlying stream: verify motif reuse
    assert b["tokens"].max() < 100 and b["targets"].max() < 100


def test_file_source(tmp_path):
    path = tmp_path / "tokens.bin"
    np.arange(10_000, dtype=np.uint16).tofile(path)
    cfg = PipelineConfig(vocab=500, seq_len=32, global_batch=4)
    src = make_source(cfg, str(path))
    b = src.batch_at(3)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])


# -- checkpointing ------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "packed": jnp.arange(16, dtype=jnp.uint32)},
            "step": jnp.int32(5)}


def test_ckpt_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(d, 10, tree, extra={"arch": "llama3.2-3b"})
    assert ckpt.latest_step(d) == 10
    got, man = ckpt.restore(d, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.manifest_extra(d)["arch"] == "llama3.2-3b"


def test_ckpt_retention_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, _tree(s), keep_n=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(d) == 5


def test_ckpt_crash_mid_write_ignored(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _tree())
    # simulate a crashed write
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1
    got, _ = ckpt.restore(d, _tree())
    assert int(got["step"]) == 5


def test_ckpt_structure_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _tree())
    with pytest.raises(ValueError):
        ckpt.restore(d, {"params": {"w": jnp.zeros((8, 8))}})
