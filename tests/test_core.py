"""Unit + property tests for repro.core (quantize, pack, requant, qlinear)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import pack, qlinear, quantize, requant
from repro.core.precision import LayerQuant, get_policy, POLICIES
from repro.core.quantize import QuantSpec

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# quantizers
# ---------------------------------------------------------------------------

def test_binarize_values_and_grad():
    x = jnp.array([-2.0, -0.3, 0.0, 0.3, 2.0])
    q = quantize.binarize(x)
    np.testing.assert_array_equal(np.asarray(q), [-1, -1, 1, 1, 1])
    # STE: gradient 1 inside [-1,1], 0 outside
    g = jax.grad(lambda v: jnp.sum(quantize.binarize(v)))(x)
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 0])


def test_ternarize_values():
    x = jnp.array([-1.0, -0.01, 0.0, 0.01, 1.0])
    q = quantize.ternarize(x, threshold=0.1)
    np.testing.assert_array_equal(np.asarray(q), [-1, 0, 0, 0, 1])


def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    s = quantize.int8_scale(x, axis=(0,))
    q = quantize.quantize_int8(x, s)
    assert jnp.max(jnp.abs(q - x)) <= jnp.max(s) * 0.5 + 1e-6


@given(st.sampled_from(["binary", "ternary", "int8", "none"]))
@settings(max_examples=8, deadline=None)
def test_fake_quant_idempotent(precision):
    """Property: fake-quant is idempotent (q(q(x)) == q(x))."""
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    spec = QuantSpec(precision)
    q1 = quantize.fake_quant(x, spec)
    q2 = quantize.fake_quant(q1, spec)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-5)


def test_fake_quant_representable_values():
    """binary -> {-a, +a}; ternary -> {-a, 0, +a} (XNOR-Net alpha scale)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (128,))
    qb = np.asarray(quantize.fake_quant(x, QuantSpec("binary")))
    assert len(np.unique(np.abs(qb))) == 1          # single magnitude
    qt = np.asarray(quantize.fake_quant(x, QuantSpec("ternary")))
    mags = np.unique(np.abs(qt))
    assert len(mags) <= 2 and 0.0 in mags            # {0, alpha}


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

@given(st.integers(1, 4).map(lambda i: i * 32), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip(k, seed):
    codes = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (3, k)).astype(jnp.uint8)
    words = pack.pack_bits(codes)
    assert words.shape == (3, k // 32)
    np.testing.assert_array_equal(np.asarray(pack.unpack_bits(words, k)), np.asarray(codes))


def test_pack_binary_roundtrip():
    v = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(3), 0.5, (5, 64)), 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(pack.unpack_binary(pack.pack_binary(v), 64)),
                                  np.asarray(v))


def test_pack_ternary_roundtrip():
    v = jnp.asarray(np.random.default_rng(0).integers(-1, 2, (4, 96)).astype(np.float32))
    m, s = pack.pack_ternary(v)
    np.testing.assert_array_equal(np.asarray(pack.unpack_ternary(m, s, 96)), np.asarray(v))


def test_pack_rejects_bad_k():
    with pytest.raises(ValueError):
        pack.pack_bits(jnp.zeros((4, 33), jnp.uint8))


def _rand_codes(rng, bits, shape):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return rng.integers(lo, hi + 1, size=shape).astype(np.int8)


@given(st.sampled_from([4, 8]), st.integers(1, 5).map(lambda i: i * 32),
       st.integers(0, 2**31 - 1))
@settings(max_examples=24, deadline=None)
def test_pack_planes_roundtrip_and_truncation_floor(bits, k, seed):
    """Property (bit-plane weight cells): a full plane stack reproduces the
    two's-complement codes EXACTLY — negative extremes included, odd word
    counts included — and slicing to the P leading MSB planes with UNCHANGED
    coefficients is the floor truncation floor(c / 2^(b-P)) * 2^(b-P), which
    is what the self-speculative draft contracts to."""
    rng = np.random.default_rng(seed)
    codes = _rand_codes(rng, bits, (6, k))
    codes[0, 0] = -(1 << (bits - 1))        # sign plane carries -2^(b-1)
    codes[0, 1] = (1 << (bits - 1)) - 1
    planes = pack.pack_planes(jnp.asarray(codes), bits)
    assert planes.shape == (bits, 6, k // pack.WORD)
    assert planes.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(pack.unpack_planes_i8(planes, k, bits)), codes)
    for keep in range(1, bits + 1):
        trunc = np.asarray(pack.unpack_planes_i8(planes[:keep], k, bits))
        want = (codes.astype(np.int32) >> (bits - keep)) << (bits - keep)
        np.testing.assert_array_equal(trunc.astype(np.int32), want,
                                      err_msg=f"keep={keep}")


def test_pack_planes_expert_axis_and_coeffs():
    """Leading (expert) dims stack the plane axis at -3; the MSB-first
    coefficient tuple is static python ints (jit-safe truncation)."""
    rng = np.random.default_rng(3)
    codes = _rand_codes(rng, 4, (2, 5, 64))
    planes = pack.pack_planes(jnp.asarray(codes), 4)
    assert planes.shape == (2, 4, 5, 2)
    np.testing.assert_array_equal(
        np.asarray(pack.unpack_planes_i8(planes, 64, 4)), codes)
    assert pack.plane_coeffs(4) == (-8, 4, 2, 1)
    assert pack.plane_coeffs(8)[0] == -128
    assert sum(pack.plane_coeffs(8)[1:]) == 127
    for bad in (1, 9):
        with pytest.raises(ValueError):
            pack.plane_coeffs(bad)


def test_pack_planes_k_quantum_and_shardability():
    """w_planes packs 32 K-operands per word (K_QUANTUM) and follows the
    same whole-word TP-shardability predicate as every bit-plane format;
    non-multiple-of-32 K and vector inputs are rejected."""
    assert pack.K_QUANTUM["w_planes"] == pack.WORD
    assert pack.shardable_words(96 // pack.WORD, 3)
    assert not pack.shardable_words(96 // pack.WORD, 2)
    with pytest.raises(ValueError):
        pack.pack_planes(jnp.zeros((4, 33), jnp.int8), 4)
    with pytest.raises(ValueError):
        pack.pack_planes(jnp.zeros((64,), jnp.int8), 4)


@given(st.integers(0, 2**31 - 1), st.integers(1, 3).map(lambda i: i * 32))
@settings(max_examples=20, deadline=None)
def test_binary_dot_matches_float(seed, k):
    """Property: XNOR-popcount dot == float dot for ±1 vectors (paper §II-A)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jnp.where(jax.random.bernoulli(kx, 0.5, (k,)), 1.0, -1.0)
    w = jnp.where(jax.random.bernoulli(kw, 0.5, (k,)), 1.0, -1.0)
    got = pack.binary_dot_words(pack.pack_binary(x), pack.pack_binary(w), k)
    assert int(got) == int(jnp.dot(x, w))


@given(st.integers(0, 2**31 - 1), st.integers(1, 3).map(lambda i: i * 32))
@settings(max_examples=20, deadline=None)
def test_ternary_dot_matches_float(seed, k):
    """Property: gated-XNOR popcount dot == float dot for trit vectors."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-1, 2, (k,)).astype(np.float32))
    w = jnp.asarray(rng.integers(-1, 2, (k,)).astype(np.float32))
    xm, xs = pack.pack_ternary(x)
    wm, ws = pack.pack_ternary(w)
    got = pack.ternary_dot_words(xm, xs, wm, ws)
    assert int(got) == int(jnp.dot(x, w))


# ---------------------------------------------------------------------------
# requant
# ---------------------------------------------------------------------------

def test_requantize_formats():
    acc = jnp.arange(-8, 8, dtype=jnp.int32)
    s = jnp.float32(0.25)
    rb = np.asarray(requant.requantize(acc, s, None, "binary"))
    assert set(np.unique(rb)) <= {-1.0, 1.0}
    rt = np.asarray(requant.requantize(acc, s, None, "ternary"))
    assert set(np.unique(rt)) <= {-1.0, 0.0, 1.0}
    ri = np.asarray(requant.requantize(acc * 1000, s, None, "int8"))
    assert ri.min() >= -127 and ri.max() <= 127


def test_match_scales_residual_identity():
    """Residual addition with matched scales equals float addition (§IV-A)."""
    a, b = jnp.float32(3.0), jnp.float32(5.0)
    sa, sb = jnp.float32(0.5), jnp.float32(0.125)
    common, ma, mb = requant.match_scales(sa, sb)
    np.testing.assert_allclose(float((a * ma + b * mb) * common),
                               float(a * sa + b * sb), rtol=1e-6)


# ---------------------------------------------------------------------------
# qlinear: serve backends agree with the QAT forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wprec,aprec", [
    ("binary", "binary"), ("binary", "none"),
    ("ternary", "ternary"), ("ternary", "none"),
    ("int8", "int8"), ("int8", "none"), ("none", "none"),
    ("ternary", "int8"), ("int4", "int8"), ("int4", "none"),
])
@pytest.mark.parametrize("impl", ["popcount", "mxu"])
def test_qlinear_serve_close_to_train(wprec, aprec, impl):
    """Packed serve path ≈ fake-quant train path (same quantized algebra)."""
    spec = qlinear.QLinearSpec(64, 32, LayerQuant(QuantSpec(wprec), QuantSpec(aprec)))
    p = qlinear.init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64)) * 0.1
    ps = qlinear.pack_params(p, spec)
    y = qlinear.apply(ps, x, spec, mode="serve", impl=impl).astype(jnp.float32)
    assert y.shape == (4, 32)
    assert not bool(jnp.any(jnp.isnan(y)))
    if wprec == "binary" and aprec == "binary":
        # exact algebra check: popcount == mxu formulation
        y2 = qlinear.apply(ps, x, spec, mode="serve",
                           impl="mxu" if impl == "popcount" else "popcount")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y2, np.float32), rtol=2e-2, atol=1e-3)


def test_qlinear_serve_param_shapes_match_packed():
    """serve_param_shapes (dry-run specs) == pack_params shapes/dtypes."""
    for wprec in ["binary", "ternary", "int4", "int8", "none"]:
        for experts in [0, 4]:
            spec = qlinear.QLinearSpec(
                64, 32, LayerQuant(QuantSpec(wprec), QuantSpec("none")),
                use_bias=True, experts=experts)
            p = qlinear.init(jax.random.PRNGKey(0), spec)
            packed = qlinear.pack_params(p, spec)
            specs = qlinear.serve_param_shapes(spec)
            assert set(packed) == set(specs), (wprec, experts)
            for k in packed:
                assert packed[k].shape == specs[k].shape, (wprec, experts, k)
                assert packed[k].dtype == specs[k].dtype, (wprec, experts, k)


def test_qlinear_experts_vmap():
    spec = qlinear.QLinearSpec(32, 16, LayerQuant(QuantSpec("int8"), QuantSpec("int8")),
                               experts=3)
    p = qlinear.init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 32)) * 0.1
    yt = qlinear.apply(p, x, spec, mode="train")
    assert yt.shape == (3, 5, 16)
    ps = qlinear.pack_params(p, spec)
    ys = qlinear.apply(ps, x, spec, mode="serve")
    assert ys.shape == (3, 5, 16)


def test_qlinear_qat_grad_flows():
    """STE: gradients reach the master weights through quantization."""
    spec = qlinear.QLinearSpec(16, 8, LayerQuant(QuantSpec("binary"), QuantSpec("binary")))
    p = qlinear.init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    g = jax.grad(lambda pp: jnp.sum(qlinear.apply(pp, x, spec) ** 2))(p)
    assert float(jnp.sum(jnp.abs(g["w"]))) > 0


# ---------------------------------------------------------------------------
# precision policy
# ---------------------------------------------------------------------------

def test_policy_first_last_override():
    pol = get_policy("mixed")
    assert pol.lookup("ffn_up").weights.precision == "ternary"
    assert pol.lookup("ffn_up", is_first=True).weights.precision == "int8"
    assert pol.lookup("moe_router").weights.precision == "none"  # always wide


def test_all_policies_resolve_all_classes():
    from repro.core.precision import LAYER_CLASSES
    from repro.core.quantize import BITS
    for pol in POLICIES.values():
        for lc in LAYER_CLASSES:
            lq = pol.lookup(lc)
            assert lq.weights.precision in BITS
            assert lq.acts.precision in BITS
