"""The dispatch-layer contract: one qgemm entry point, every operating point.

Two guarantees the refactor must keep forever:
  1. jnp and Pallas backends agree for EVERY registered (wprec, aprec, impl)
     cell — including bias fusion and the expert axis — because they share
     one activation-prep and one requant implementation per cell.
  2. every operating point the POLICIES table can produce resolves to a
     registered cell (adding a policy without a kernel is a test failure,
     not a runtime KeyError).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qlinear
from repro.core.precision import LAYER_CLASSES, LayerQuant, POLICIES
from repro.core.quantize import QuantSpec
from repro.kernels import dispatch, harness

CELLS = sorted(dispatch.cells())


def _spec(wprec, aprec, *, bias=False, experts=0, k=64, n=32):
    return qlinear.QLinearSpec(
        k, n, LayerQuant(QuantSpec(wprec), QuantSpec(aprec)),
        use_bias=bias, experts=experts)


def _packed(spec, seed=0):
    p = qlinear.init(jax.random.PRNGKey(seed), spec)
    if spec.use_bias:
        p["b"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                   p["b"].shape) * 0.1
    return qlinear.pack_params(p, spec)


# ---------------------------------------------------------------------------
# 1. jnp-vs-pallas equivalence, all cells × bias × experts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wprec,aprec,impl", CELLS)
@pytest.mark.parametrize("bias", [False, True])
def test_qgemm_backends_agree(wprec, aprec, impl, bias):
    impl_arg = "popcount" if impl == "*" else impl
    spec = _spec(wprec, aprec, bias=bias)
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (5, spec.in_dim)) * 0.2
    yj = dispatch.qgemm(p, x, spec, impl=impl_arg, backend="jnp")
    yp = dispatch.qgemm(p, x, spec, impl=impl_arg, backend="pallas")
    assert yj.shape == yp.shape == (5, spec.out_dim)
    np.testing.assert_allclose(np.asarray(yj, np.float32),
                               np.asarray(yp, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("wprec,aprec,impl", CELLS)
def test_qgemm_expert_axis(wprec, aprec, impl):
    impl_arg = "popcount" if impl == "*" else impl
    spec = _spec(wprec, aprec, bias=True, experts=3)
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 4, spec.in_dim)) * 0.2
    yj = dispatch.qgemm(p, x, spec, impl=impl_arg, backend="jnp")
    yp = dispatch.qgemm(p, x, spec, impl=impl_arg, backend="pallas")
    assert yj.shape == yp.shape == (3, 4, spec.out_dim)
    np.testing.assert_allclose(np.asarray(yj, np.float32),
                               np.asarray(yp, np.float32),
                               rtol=2e-2, atol=2e-2)
    # expert slices differ (the vmap really maps the weights)
    y0, y1 = np.asarray(yj, np.float32)[0], np.asarray(yj, np.float32)[1]
    assert np.abs(y0 - y1).max() > 0


def test_qgemm_bias_fused_matches_manual():
    """Bias must land inside the requant epilogue, not as a post-hoc add in a
    different dtype — fused-vs-manual must agree to bf16 resolution."""
    spec = _spec("int8", "int8", bias=True)
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, spec.in_dim)) * 0.2
    for backend in ("jnp", "pallas"):
        y = dispatch.qgemm(p, x, spec, backend=backend)
        p_nob = {k: v for k, v in p.items() if k != "b"}
        y_nob = dispatch.qgemm(p_nob, x, spec, backend=backend)
        manual = np.asarray(y_nob, np.float32) + np.asarray(p["b"], np.float32)
        np.testing.assert_allclose(np.asarray(y, np.float32), manual,
                                   rtol=2e-2, atol=2e-2)


def test_qgemm_nonaligned_rows_padded():
    """M not a sublane multiple: dispatch pads, runs, unpads."""
    spec = _spec("binary", "binary")
    p = _packed(spec)
    for m in (1, 3, 7, 13):
        x = jax.random.normal(jax.random.PRNGKey(m), (m, spec.in_dim)) * 0.2
        yj = dispatch.qgemm(p, x, spec, backend="jnp")
        yp = dispatch.qgemm(p, x, spec, backend="pallas")
        assert yj.shape == yp.shape == (m, spec.out_dim)
        np.testing.assert_allclose(np.asarray(yj, np.float32),
                                   np.asarray(yp, np.float32),
                                   rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# 2. registry completeness over the POLICIES table
# ---------------------------------------------------------------------------

def test_every_policy_operating_point_resolves():
    seen = set()
    for pol in POLICIES.values():
        for lc in LAYER_CLASSES:
            for first, last in ((False, False), (True, False), (False, True)):
                lq = pol.lookup(lc, is_first=first, is_last=last)
                for impl in ("popcount", "mxu"):
                    cell = dispatch.lookup(lq.weights.precision,
                                           lq.acts.precision, impl)
                    seen.add(cell.key)
    # and the W&A cells all carry a Pallas body (packed serve path exists)
    for key, cell in dispatch.cells().items():
        if cell.aprec != "none":
            assert cell.body is not None, key
    assert seen  # sanity: the sweep visited the registry


def test_unknown_operating_point_raises():
    with pytest.raises(KeyError, match="no GEMM registered"):
        dispatch.lookup("int4", "int4", "popcount")


def test_duplicate_registration_rejected():
    cell = dispatch.lookup("binary", "binary", "popcount")
    with pytest.raises(ValueError, match="duplicate"):
        dispatch.register(cell)


def test_vmem_tile_model_within_budget():
    """Every registered Pallas body fits VMEM at default blocks (<<128 MiB)."""
    for key, cell in dispatch.cells().items():
        if cell.body is None:
            continue
        assert harness.vmem_tile_bytes(cell.body) < 16 * 2**20, key
