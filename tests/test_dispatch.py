"""The dispatch-layer contract: one qgemm entry point, every operating point.

Guarantees the OperatingPoint redesign must keep forever:
  1. jnp and Pallas backends agree for EVERY registered cell — including
     bias fusion, the expert axis, and the mixed w/a + int4 cells — because
     they share one activation-prep and one requant implementation per cell.
  2. every cell is BIT-exact against a dequantize-then-fp32 reference oracle
     built only from the `core.pack` codec contract (hypothesis property:
     the integer dot of the stored codes times the stored scales IS the
     output, to bf16 resolution, on both backends, with bias and experts).
  3. every operating point the POLICIES table can produce resolves to a
     registered cell — the sweep is REGENERATED from
     `precision.policy_operating_points()`, so adding a policy without a
     kernel is a test failure, not a runtime KeyError.
  4. the OperatingPoint/TuneTable API invariants: registry keys are
     structured, lookup failures suggest the nearest cell, tune tables
     round-trip through JSON, and a point that contradicts the layer's
     policy assignment is rejected loudly.

Row-parallel/column-parallel TP exactness for every cell (including the new
ones — the sweep is registry-driven) lives in tests/test_dispatch_tp.py.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import pack, qlinear
from repro.core.precision import (LayerQuant, POLICIES,
                                  policy_operating_points)
from repro.core.quantize import QuantSpec, int8_codes
from repro.kernels import dispatch, harness
from repro.kernels.dispatch import OperatingPoint, Tile, TuneTable

CELLS = sorted(dispatch.cells())
NEW_CELLS = [k for k in CELLS
             if k[:2] in (("ternary", "int8"), ("int4", "int8"),
                          ("int4", "none"))]


def _spec(wprec, aprec, *, bias=False, experts=0, k=64, n=32):
    return qlinear.QLinearSpec(
        k, n, LayerQuant(QuantSpec(wprec), QuantSpec(aprec)),
        use_bias=bias, experts=experts)


def _packed(spec, seed=0):
    p = qlinear.init(jax.random.PRNGKey(seed), spec)
    if spec.use_bias:
        p["b"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                   p["b"].shape) * 0.1
    return qlinear.pack_params(p, spec)


def _op(spec, impl, backend="jnp"):
    return OperatingPoint.for_spec(spec, impl=impl, backend=backend)


def _impl_arg(impl):
    return "popcount" if impl == "*" else impl


# ---------------------------------------------------------------------------
# 1. jnp-vs-pallas equivalence, all cells × bias × experts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wprec,aprec,impl", CELLS)
@pytest.mark.parametrize("bias", [False, True])
def test_qgemm_backends_agree(wprec, aprec, impl, bias):
    spec = _spec(wprec, aprec, bias=bias)
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (5, spec.in_dim)) * 0.2
    yj = dispatch.qgemm(p, x, spec, _op(spec, _impl_arg(impl)))
    yp = dispatch.qgemm(p, x, spec, _op(spec, _impl_arg(impl), "pallas"))
    assert yj.shape == yp.shape == (5, spec.out_dim)
    np.testing.assert_allclose(np.asarray(yj, np.float32),
                               np.asarray(yp, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("wprec,aprec,impl", CELLS)
def test_qgemm_expert_axis(wprec, aprec, impl):
    spec = _spec(wprec, aprec, bias=True, experts=3)
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 4, spec.in_dim)) * 0.2
    yj = dispatch.qgemm(p, x, spec, _op(spec, _impl_arg(impl)))
    yp = dispatch.qgemm(p, x, spec, _op(spec, _impl_arg(impl), "pallas"))
    assert yj.shape == yp.shape == (3, 4, spec.out_dim)
    np.testing.assert_allclose(np.asarray(yj, np.float32),
                               np.asarray(yp, np.float32),
                               rtol=2e-2, atol=2e-2)
    # expert slices differ (the vmap really maps the weights)
    y0, y1 = np.asarray(yj, np.float32)[0], np.asarray(yj, np.float32)[1]
    assert np.abs(y0 - y1).max() > 0


def test_qgemm_bias_fused_matches_manual():
    """Bias must land inside the requant epilogue, not as a post-hoc add in a
    different dtype — fused-vs-manual must agree to bf16 resolution."""
    spec = _spec("int8", "int8", bias=True)
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, spec.in_dim)) * 0.2
    for backend in ("jnp", "pallas"):
        op = _op(spec, "popcount", backend)
        y = dispatch.qgemm(p, x, spec, op)
        p_nob = {k: v for k, v in p.items() if k != "b"}
        y_nob = dispatch.qgemm(p_nob, x, spec, op)
        manual = np.asarray(y_nob, np.float32) + np.asarray(p["b"], np.float32)
        np.testing.assert_allclose(np.asarray(y, np.float32), manual,
                                   rtol=2e-2, atol=2e-2)


def test_qgemm_nonaligned_rows_padded():
    """M not a sublane multiple: dispatch pads, runs, unpads."""
    spec = _spec("binary", "binary")
    p = _packed(spec)
    for m in (1, 3, 7, 13):
        x = jax.random.normal(jax.random.PRNGKey(m), (m, spec.in_dim)) * 0.2
        yj = dispatch.qgemm(p, x, spec, _op(spec, "popcount"))
        yp = dispatch.qgemm(p, x, spec, _op(spec, "popcount", "pallas"))
        assert yj.shape == yp.shape == (m, spec.out_dim)
        np.testing.assert_allclose(np.asarray(yj, np.float32),
                                   np.asarray(yp, np.float32),
                                   rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# 2. dequantize-then-fp32 oracle: stored codes × stored scales == the output
# ---------------------------------------------------------------------------

def _dequant_codes_w(p, spec):
    """(N, K) integer/trit weight codes straight from the packed storage —
    decoded ONLY via the `core.pack` codec contract, no dispatch code."""
    k = spec.in_dim
    wprec = spec.lq.weights.precision
    if wprec == "binary":
        return pack.unpack_binary(p["w_packed"], k)
    if wprec == "ternary":
        return pack.unpack_ternary(p["w_mask"], p["w_sign"], k)
    if wprec == "int4":
        return pack.unpack_int4_i8(p["w_q4"], k).astype(jnp.float32)
    if wprec == "int8":
        return jnp.swapaxes(p["w_q"], -1, -2).astype(jnp.float32)
    return jnp.swapaxes(p["w"], -1, -2).astype(jnp.float32)


def _quant_codes_x(p, x2d, spec):
    """Activation codes + per-row scale exactly as the serve prep defines
    them (the codec is the contract; the arithmetic below is independent)."""
    from repro.core.quantize import ternarize
    aprec = spec.lq.acts.precision
    xf = x2d.astype(jnp.float32)
    if aprec == "binary":
        return jnp.where(xf >= 0, 1.0, -1.0), jnp.mean(jnp.abs(xf), axis=-1)
    if aprec == "ternary":
        q = ternarize(xf, spec.lq.acts.ternary_threshold, axis=-1)
        return jax.lax.stop_gradient(q), jnp.mean(jnp.abs(xf), axis=-1)
    if aprec == "int8":
        a = p["a_scale"]
        return int8_codes(xf, a).astype(jnp.float32), \
            jnp.full((x2d.shape[0],), a, jnp.float32)
    return None, None   # "none": bf16 activations, handled separately


def _oracle(p, x2d, spec):
    """Dequantize-then-fp32 reference, factored so every float product is
    exact: integer-code dot (exact in f32 at these ranges) -> scales ->
    bias -> bf16. Must match qgemm BIT for bit."""
    wq = _dequant_codes_w(p, spec)
    xq, asc = _quant_codes_x(p, x2d, spec)
    ws, bias = p.get("w_scale"), p.get("b")
    if xq is not None:                      # W&A cell: wide f32 requant
        acc = xq @ wq.T
        y = acc.astype(jnp.float32)
        if ws is not None:
            y = y * ws[None, :]
        y = y * asc[:, None]
        if bias is not None:
            y = y + bias[None, :]
        return y.astype(jnp.bfloat16)
    # weight-only cell: bf16 accumulate, bf16 scale, f32 bias
    acc = x2d.astype(jnp.bfloat16) @ wq.astype(jnp.bfloat16).T
    y = acc if ws is None else acc * ws.astype(acc.dtype)
    if bias is not None:
        y = y.astype(jnp.float32) + bias[None, :]
    return y.astype(jnp.bfloat16)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(NEW_CELLS), st.booleans(), st.sampled_from([0, 2]),
       st.sampled_from([64, 96, 128]), st.integers(1, 9),
       st.sampled_from(["jnp", "pallas"]), st.integers(0, 10))
def test_new_cells_bit_exact_vs_dequant_oracle(cellkey, bias, experts, k, m,
                                               backend, seed):
    """Hypothesis property: the mixed w-ternary×a-int8 and int4 cells are
    BIT-exact against the dequantize-then-fp32 oracle on both backends,
    including bias and the expert axis."""
    wprec, aprec, impl = cellkey
    spec = _spec(wprec, aprec, bias=bias, experts=experts, k=k)
    p = _packed(spec, seed=seed)
    shape = (experts, m, k) if experts else (m, k)
    x = jax.random.normal(jax.random.PRNGKey(seed + m), shape) * 0.2
    y = dispatch.qgemm(p, x, spec, _op(spec, _impl_arg(impl), backend))
    if experts:
        want = jnp.stack([
            _oracle({nm: (v if v.ndim == 0 or nm == "a_scale" else v[e])
                     for nm, v in p.items()}, x[e],
                    dataclasses.replace(spec, experts=0))
            for e in range(experts)])
    else:
        want = _oracle(p, x, spec)
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(want, np.float32),
        err_msg=str((cellkey, bias, experts, k, m, backend, seed)))


@pytest.mark.parametrize("wprec,aprec,impl", CELLS)
def test_all_cells_match_dequant_oracle(wprec, aprec, impl):
    """The same oracle, every registered cell once (deterministic sweep)."""
    spec = _spec(wprec, aprec, bias=True)
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, spec.in_dim)) * 0.2
    y = dispatch.qgemm(p, x, spec, _op(spec, _impl_arg(impl)))
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(_oracle(p, x, spec), np.float32),
                                  err_msg=str((wprec, aprec, impl)))


@settings(max_examples=16, deadline=None)
@given(st.sampled_from([("int4", "int8"), ("int8", "int8")]),
       st.integers(1, 8), st.sampled_from(["jnp", "pallas"]),
       st.integers(0, 5))
def test_plane_truncation_matches_snapped_code_oracle(pair, keep, backend,
                                                      seed):
    """OperatingPoint.planes truncation (the self-speculative draft's
    contract): running a plane cell on its P leading MSB planes with the
    ORIGINAL coefficients is bit-identical to the full fp32 oracle over
    floor-snapped codes floor(c / 2^(b-P)) * 2^(b-P) — and the full-depth
    stack is bit-identical to the formulation-agnostic direct cell."""
    wprec, aprec = pair
    bits = pack.PLANE_BITS[wprec]
    keep = min(keep, bits)
    spec = _spec(wprec, aprec)
    p = _packed(spec, seed=seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 11),
                          (4, spec.in_dim)) * 0.2
    op = dataclasses.replace(_op(spec, "planes", backend), planes=keep)
    y = dispatch.qgemm(p, x, spec, op)
    codes = np.asarray(pack.unpack_planes_i8(
        p["w_planes"], spec.in_dim, bits)).astype(np.int32)
    snapped = (codes >> (bits - keep)) << (bits - keep)      # (N, K) floor
    xq, asc = _quant_codes_x(p, x, spec)
    want = (xq @ jnp.asarray(snapped, jnp.float32).T
            ).astype(jnp.float32) * p["w_scale"][None, :] * asc[:, None]
    np.testing.assert_array_equal(
        np.asarray(y, np.float32),
        np.asarray(want.astype(jnp.bfloat16), np.float32),
        err_msg=str((pair, keep, backend, seed)))
    if keep == bits:
        direct = dispatch.qgemm(p, x, spec, _op(spec, "popcount", backend))
        np.testing.assert_array_equal(np.asarray(y, np.float32),
                                      np.asarray(direct, np.float32))


# ---------------------------------------------------------------------------
# 3. registry completeness — regenerated from the POLICIES table
# ---------------------------------------------------------------------------

def test_every_policy_operating_point_resolves():
    """Every (wprec, aprec) pair any policy can assign to any layer class —
    `policy_operating_points()` regenerates the list, so a new POLICIES
    entry automatically extends this obligation — resolves under both
    formulations, and every W&A cell carries a Pallas body."""
    pts = policy_operating_points()
    assert ("ternary", "int8") in pts and ("int4", "int8") in pts  # new cells
    seen = set()
    for wprec, aprec in pts:
        for impl in ("popcount", "mxu"):
            cell = dispatch.lookup(wprec, aprec, impl)
            seen.add(cell.key)
    for key, cell in dispatch.cells().items():
        if cell.aprec != "none":
            assert cell.body is not None, key
    assert seen  # sanity: the sweep visited the registry


def test_policies_cover_every_registered_cell():
    """The converse: no registry cell is policy-unreachable (dead kernels
    rot — every cell must be nameable by some POLICIES entry)."""
    pts = policy_operating_points()
    for key, cell in dispatch.cells().items():
        assert (cell.wprec, cell.aprec) in pts, key


def test_unknown_operating_point_raises_with_suggestion():
    with pytest.raises(KeyError, match="no GEMM registered") as ei:
        dispatch.lookup("int4", "int4", "popcount")
    # wildcard-aware nearest-cell suggestion, not a raw registry dump
    assert "nearest registered cell" in str(ei.value)
    assert "wprec='int4'" in str(ei.value)
    assert "--list" in str(ei.value)


def test_duplicate_registration_rejected():
    cell = dispatch.lookup("binary", "binary", "popcount")
    with pytest.raises(ValueError, match="duplicate"):
        dispatch.register(cell)


def test_vmem_tile_model_within_budget():
    """Every registered Pallas body fits VMEM at its tuned/default tile."""
    tune = dispatch.default_tune()
    for key, cell in dispatch.cells().items():
        if cell.body is None:
            continue
        tile = tune.tile_for(cell.op)
        assert harness.vmem_tile_bytes(cell.body, tile) < 16 * 2**20, key


# ---------------------------------------------------------------------------
# 4. OperatingPoint / TuneTable API invariants
# ---------------------------------------------------------------------------

def test_operating_point_mismatch_rejected():
    """An op whose precisions contradict the layer's policy assignment is a
    loud error — per-layer resolution may never silently run the wrong cell."""
    spec = _spec("ternary", "int8")
    p = _packed(spec)
    x = jnp.zeros((2, spec.in_dim))
    with pytest.raises(ValueError, match="does not match"):
        dispatch.qgemm(p, x, spec, OperatingPoint("int8", "int8"))


def test_legacy_kwargs_still_resolve():
    """Out-of-tree form: qgemm(..., impl=, backend=) == the op form."""
    spec = _spec("ternary", "ternary")
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, spec.in_dim)) * 0.2
    a = dispatch.qgemm(p, x, spec, impl="mxu", backend="pallas")
    b = dispatch.qgemm(p, x, spec,
                       OperatingPoint.for_spec(spec, impl="mxu",
                                               backend="pallas"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="not both"):
        dispatch.qgemm(p, x, spec, OperatingPoint.for_spec(spec), impl="mxu")


def test_operating_point_validates_backend():
    with pytest.raises(ValueError, match="backend"):
        OperatingPoint("int8", "int8", backend="tpu")


def test_tile_override_changes_blocks_not_results():
    """An explicit OperatingPoint tile is honored (block-size invariance of
    the harness) and the TuneTable default gives identical values."""
    spec = _spec("binary", "binary", k=128, n=64)
    p = _packed(spec)
    x = jax.random.normal(jax.random.PRNGKey(7), (8, spec.in_dim)) * 0.2
    base = dispatch.qgemm(p, x, spec, _op(spec, "popcount", "pallas"))
    tiled = dispatch.qgemm(
        p, x, spec, dataclasses.replace(_op(spec, "popcount", "pallas"),
                                        tile=Tile(bm=8, bn=32, bkq=1)))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tiled))


def test_tune_table_roundtrip(tmp_path):
    t = TuneTable(tiles={("binary", "binary", "popcount"): Tile(64, 128, 8),
                         ("int4", "int8", "*"): Tile(128, 128, 32)},
                  source="unit test")
    path = str(tmp_path / "tune.json")
    t.save(path)
    back = TuneTable.load(path)
    assert back.tiles == dict(t.tiles) and back.source == "unit test"
    # wildcard-aware resolution, same fallback as lookup()
    assert back.tile_for(OperatingPoint("int4", "int8", "mxu")) == \
        Tile(128, 128, 32)
    assert back.tile_for(OperatingPoint("none", "none")) is None
    with open(path) as f:
        assert set(json.load(f)) == {"source", "cells"}


def test_exact_key_beats_wildcard_regardless_of_order(tmp_path):
    """Precedence pin: an exact (wprec, aprec, impl) row wins over the
    (wprec, aprec, '*') wildcard in BOTH lookup() and TuneTable.tile_for,
    independent of JSON/registration order. The plane-composed cells coexist
    with the formulation-agnostic int4/int8 wildcard cell exactly because of
    this rule — a regression here silently reroutes --impl planes to the
    dense-code cell."""
    # registry side: the exact planes cell resolves, other impls hit '*'
    planes = dispatch.lookup("int4", "int8", "planes")
    assert planes.key == ("int4", "int8", "planes")
    assert "w_planes" in planes.weight_names
    assert dispatch.lookup("int4", "int8", "mxu").key == ("int4", "int8", "*")
    assert dispatch.lookup("int8", "int8", "planes").key == \
        ("int8", "int8", "planes")
    # tune-table side: exact-over-wildcard for either insertion order
    rows = {"int4/int8/*": {"bm": 128, "bn": 128, "bkq": 64},
            "int4/int8/planes": {"bm": 32, "bn": 32, "bkq": 8}}
    for name, order in (("wild_first", list(rows)),
                        ("exact_first", list(rows)[::-1])):
        path = str(tmp_path / f"{name}.json")
        with open(path, "w") as f:
            json.dump({"source": name,
                       "cells": {k: rows[k] for k in order}}, f)
        tune = TuneTable.load(path)
        assert tune.tile_for(OperatingPoint("int4", "int8", "planes")) == \
            Tile(32, 32, 8), name
        assert tune.tile_for(OperatingPoint("int4", "int8", "popcount")) == \
            Tile(128, 128, 64), name
    # the shipped table pins the plane cells explicitly
    shipped = dispatch.default_tune()
    for key in (("int4", "int8", "planes"), ("int8", "int8", "planes")):
        assert key in shipped.tiles, key


def test_shipped_tune_table_keys_are_registered():
    """The in-repo CPU table may only name live registry cells (a retune
    after a registry change must not leave stale keys behind) — plus the one
    non-GEMM pseudo-cell, the paged-attention decode kernel's pages-per-block
    Tile (kernels/paged_attn.TUNE_KEY)."""
    from repro.kernels.paged_attn import TUNE_KEY
    tune = dispatch.default_tune()
    assert tune.tiles, "shipped tune_cpu.json missing or empty"
    for key in tune.tiles:
        assert key in dispatch.cells() or key == TUNE_KEY, key
    assert TUNE_KEY in tune.tiles, "paged-attn Tile missing from shipped table"


def test_prune_stale_tiles_drops_unresolvable_keys():
    """`kernel_bench --retune` prunes tune-table rows no registered cell can
    resolve (renamed impl, retired precision pair) while keeping every live
    row, the `(w, a, "*")` wildcards of registered pairs, and the paged-attn
    pseudo-cell."""
    from repro.kernels.paged_attn import TUNE_KEY
    tune = dispatch.default_tune()
    stale = {("int3", "int8", "*"): Tile(64, 64, 16),
             ("binary", "binary", "gone-impl"): Tile(128, 128, 8)}
    wild = ("binary", "binary", "*")          # registered pair: must survive
    assert wild in dispatch.valid_tune_keys()
    tiles = {**tune.tiles, **stale, wild: Tile(64, 64, 8)}
    kept, dropped = dispatch.prune_stale_tiles(tiles, extra_keys=(TUNE_KEY,))
    assert dropped == sorted(stale)
    assert set(kept) == set(tune.tiles) | {wild}
    # without the extra key, the pseudo-cell row is pruned too (the prune is
    # exactly as permissive as its caller declares)
    kept2, dropped2 = dispatch.prune_stale_tiles(tune.tiles)
    assert TUNE_KEY in dropped2 and TUNE_KEY not in kept2


def test_registry_table_renders():
    table = dispatch.registry_table()
    assert "wprec" in table and "int4" in table and "w_q4" in table
