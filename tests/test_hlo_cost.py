"""Validate the trip-count-aware HLO cost model against XLA's cost_analysis
on unrolled programs (where XLA is trustworthy) and against analytic counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    x = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    c = _compiled(lambda a, b: a @ b, x, w)
    cost = hlo_cost.analyze_compiled(c)
    assert cost.flops == 2 * 128 * 64 * 32


def test_scan_trip_count_multiplies():
    """The whole reason this module exists: scanned == unrolled cost."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    cs = hlo_cost.analyze_compiled(_compiled(scanned, x, ws))
    cu = hlo_cost.analyze_compiled(_compiled(unrolled, x, ws))
    dot_flops = 8 * 2 * 256 ** 3
    assert cs.flops >= dot_flops
    # scanned and unrolled agree within elementwise noise (<2%)
    np.testing.assert_allclose(cs.flops, cu.flops, rtol=0.02)
    # and XLA's own (trustworthy on unrolled) count agrees
    xla = cu and _compiled(unrolled, x, ws).cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    np.testing.assert_allclose(cs.flops, float(xla["flops"]), rtol=0.02)


def test_nested_scan_multiplies():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def ob(x, _):
            return jax.lax.scan(inner, x, ws)[0], None
        return jax.lax.scan(ob, x, None, length=5)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    cost = hlo_cost.analyze_compiled(_compiled(outer, x, ws))
    want = 5 * 3 * 2 * 64 ** 3
    assert cost.flops >= want
    assert cost.flops < want * 1.1


def test_collective_bytes_counted_with_trips():
    from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs.reshape(1), ("d",))

    def body(x, _):
        return jax.lax.psum(x, "d"), None

    def fn(x):
        return jax.lax.scan(body, x, None, length=4)[0]

    from repro.optim.compress import shard_map
    sh = NamedSharding(mesh, P())
    f = shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P())
    c = jax.jit(f, in_shardings=sh).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    cost = hlo_cost.analyze_compiled(c)
    # 4 iterations x 128x128xf32 = 256 KiB total (1-device all-reduce may be
    # optimized away; accept 0 or the full count)
    assert cost.coll_bytes in (0.0, 4 * 128 * 128 * 4) or cost.coll_bytes > 0


def test_bytes_reasonable_for_copy():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compiled(lambda a: a.T.copy(), x)
    cost = hlo_cost.analyze_compiled(c)
    assert cost.bytes >= 2 * 1024 * 1024 * 4  # read + write at least
