"""End-to-end system tests: real training runs on CPU with the reduced
configs — loss decreases, checkpoints restart bit-compatibly, failure
injection + resume works, serving produces tokens."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=900):
    r = subprocess.run([sys.executable, "-m", *args], capture_output=True,
                       text=True, env=ENV, cwd=REPO, timeout=timeout)
    assert r.returncode == 0, f"{args}:\nSTDOUT:{r.stdout[-2000:]}\nERR:{r.stderr[-2000:]}"
    return r.stdout


def test_train_loss_decreases(tmp_path):
    """Train the reduced llama for 60 steps — loss must drop measurably."""
    from repro.launch import train as train_mod
    losses = train_mod.main(["--arch", "llama3.2-3b", "--reduced",
                             "--steps", "60", "--batch", "8", "--seq", "64",
                             "--lr", "3e-3", "--log-every", "20"])
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


def test_train_quantized_policy_loss_decreases():
    """QAT path: ternary body weights still learn on CPU."""
    from repro.launch import train as train_mod
    losses = train_mod.main(["--arch", "xlstm-125m", "--reduced",
                             "--steps", "40", "--batch", "4", "--seq", "32",
                             "--lr", "3e-3", "--policy", "w-ternary",
                             "--log-every", "20"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_checkpoint_restart_resumes_stream(tmp_path):
    """Crash at step 25, resume, final state ~= uninterrupted run."""
    from repro.launch import train as train_mod
    ck1 = str(tmp_path / "ck_crash")
    args = ["--arch", "llama3.2-3b", "--reduced", "--steps", "40",
            "--batch", "4", "--seq", "32", "--ckpt-dir", ck1,
            "--ckpt-every", "10", "--log-every", "100"]
    with pytest.raises(RuntimeError, match="injected failure"):
        train_mod.main(args + ["--fail-at-step", "25"])
    from repro.checkpoint import ckpt
    resumed_from = ckpt.latest_step(ck1)
    assert resumed_from is not None and resumed_from <= 25
    losses_resumed = train_mod.main(args + ["--resume"])
    assert len(losses_resumed) == 40 - resumed_from
    assert np.isfinite(losses_resumed[-1])


def test_grad_compress_trains():
    from repro.launch import train as train_mod
    losses = train_mod.main(["--arch", "llama3.2-3b", "--reduced",
                             "--steps", "30", "--batch", "4", "--seq", "32",
                             "--grad-compress", "--log-every", "100"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_serve_driver():
    from repro.launch import serve as serve_mod
    srv = serve_mod.main(["--arch", "llama3.2-3b", "--reduced",
                          "--requests", "5", "--max-new", "6", "--slots", "2"])
    assert len(srv.completed) == 5
    assert all(len(r.out) >= 6 for r in srv.completed)


def test_elastic_restore_other_mesh(tmp_path):
    """Save on a 1-device mesh, restore through reshard_restore on a
    different layout (1x1) — shapes/values survive re-sharding."""
    from repro.checkpoint import ckpt
    from repro.launch import elastic
    from repro.launch.mesh import make_host_mesh
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "packed": jnp.arange(8, dtype=jnp.uint32)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, tree, mesh_shape=(2, 4))
    mesh = make_host_mesh(model=1)
    got, man = elastic.reshard_restore(d, tree, mesh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert man["mesh_shape"] == [2, 4]


def test_step_monitor_straggler_flags():
    from repro.launch.elastic import StepMonitor
    m = StepMonitor()
    for i in range(10):
        assert m.record(i, 1.0) is None
    v = m.record(10, 3.5)
    assert v and "straggler" in v
    m.record(11, 3.5)
    v = m.record(12, 30.0)
    assert v and "evict" in v
