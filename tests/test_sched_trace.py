"""Scheduler state-machine trace test: random interleavings of submit /
chunked-prefill / decode / preempt / resume / EOS-retire must preserve the
PageTable and lifecycle invariants at EVERY tick, and the dispatch-ahead
epoch fence must behave exactly (a prepared plan is consumed iff nothing
mutated the scheduler after it was built — a submit, fork or swap in
between always fences it).

The device calls are stubbed with numpy fakes (no jit, no model): the fake
model deterministically emits token (write_position + 1) % vocab, so the
expected output of every request is a pure function of its prompt length,
max_new and eos — computable without running a transformer. That turns the
whole scheduler into a fast, exhaustively-checkable state machine: hundreds
of random traces per second instead of seconds per trace. The real-model
byte/token exactness is locked down separately (test_serving.py,
test_serving_sched.py); THIS test's job is the bookkeeping — refcounts,
free-list conservation, state exclusivity, fence correctness — under
interleavings no hand-written test would enumerate.

Uses tests/_hypothesis_compat: real hypothesis when installed, a seeded
deterministic fallback otherwise.
"""
import dataclasses
import functools
import random

import numpy as np

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.launch.kv_cache import NULL_PAGE
from repro.launch.serve import (PREEMPTED, PREFILLING, RUNNING, WAITING,
                                Request, Server)
from repro.models import transformer
from repro.models.common import ModelCtx

CACHE_LEN = 32
PAGE_SIZE = 4
VOCAB = 512
SLOTS = 3
NUM_PAGES = 8        # 7 usable: tight enough to force preempt/defer paths


@functools.lru_cache(maxsize=None)
def _built():
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              policy="ternary")
    params = transformer.init(__import__("jax").random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    return cfg, sparams


def _stub_server(*, chunk_tokens, prefix_share):
    """A real Server whose jitted device calls are replaced by numpy fakes.
    The fake model's next token is (position_being_written + 1) % VOCAB:
    decode at position p emits p+1; the final prefill chunk of an n-token
    prompt emits n. All real host-side machinery (PageTable, swap slabs,
    CoW planning, epochs, plans) runs unchanged."""
    cfg, sparams = _built()
    srv = Server(cfg, sparams, slots=SLOTS, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=NUM_PAGES,
                 prefix_share=prefix_share, preempt=True,
                 chunk_tokens=chunk_tokens,
                 ctx=ModelCtx(mode="serve"))

    def fake_decode(params, cache, tokens, pos, pages):
        p = np.asarray(pos)
        logits = np.zeros((srv.phys_slots, 1, VOCAB), np.float32)
        logits[np.arange(srv.phys_slots), 0, (p + 1) % VOCAB] = 1.0
        return logits, cache

    def fake_chunk(params, cache, tokens, pos0, read, write, nreal, last_idx):
        nxt = (int(np.asarray(pos0)[0]) + int(np.asarray(nreal)[0])) % VOCAB
        logits = np.zeros((1, 1, VOCAB), np.float32)
        logits[0, 0, nxt] = 1.0
        return logits, cache

    def fake_prefill(*a):
        raise AssertionError("whole-prompt prefill dispatched with "
                             "chunk_tokens > 0 — chunked admission broken")

    srv._decode = fake_decode
    srv._chunk = fake_chunk
    srv._prefill = fake_prefill
    srv._cow = lambda cache, a, b: cache
    return srv


def _expected_out(req, plen):
    """The stub model's full output: n, n+1, ... truncated by max_new (and
    by eos the step it is emitted). plen + max_new <= 21 << VOCAB, so the
    eos match index is unambiguous."""
    out = [(plen + j) % VOCAB for j in range(req.max_new)]
    if req.eos is not None and req.eos in out:
        out = out[: out.index(req.eos) + 1]
    return out


def _check_invariants(srv, reqs):
    pt = srv.pt
    # -- page-table conservation: every non-free page is referenced exactly
    # refcount times by {slot tables} ∪ {share index}, free list disjoint
    assert pt.free_pages + int((pt.refcount[1:] > 0).sum()) == pt.usable_pages
    assert all(pt.refcount[p] == 0 for p in pt._free)
    for s in range(srv.slots):
        held = int(pt.held[s])
        live = pt.table[s, :held]
        assert (live != NULL_PAGE).all(), (s, pt.table[s])
        assert (pt.table[s, held:] == NULL_PAGE).all(), (s, pt.table[s])
        assert all(pt.refcount[p] > 0 for p in live), (s, live)
        r = srv.slot_req[s]
        if r is None:
            assert held == 0 and not pt.active[s]
        else:
            assert r.state in (RUNNING, PREFILLING), r.state
            assert 0 <= srv.slot_pos[s] <= CACHE_LEN
    # -- lifecycle exclusivity: one home per request, states consistent
    slotted = [r for r in srv.slot_req if r is not None]
    for r in reqs:
        homes = (int(r in srv.queue) + int(r in slotted)
                 + int(r in srv.preempted) + int(r in srv.completed))
        assert homes == 1, (r.rid, r.state, homes)
    for r in srv.preempted:
        # never simultaneously PREFILLING and PREEMPTED: a partial-chunk
        # swap image does not exist
        assert r.state == PREEMPTED, (r.rid, r.state)
        assert r.rid in srv._swap
    for s, r in enumerate(srv.slot_req):
        if r is not None and r.state == PREFILLING:
            assert s in srv._prefill_ctx
    for s in srv._prefill_ctx:
        assert (srv.slot_req[s] is not None
                and srv.slot_req[s].state == PREFILLING)
    # -- fence sanity: a plan from the future cannot exist
    if srv._prepared is not None:
        assert srv._prepared.epoch <= srv._epoch


def _step_checked(srv, reqs):
    """One tick with the fence contract asserted exactly: a prepared plan is
    consumed iff its epoch snapshot still matches — any submit/fork/swap/
    loud-retire since the build must fence it."""
    prep, epoch = srv._prepared, srv._epoch
    hits, fences = srv.stats["plan_hits"], srv.stats["fences"]
    srv.step()
    if prep is not None:
        if prep.epoch == epoch:
            assert srv.stats["plan_hits"] == hits + 1
            assert srv.stats["fences"] == fences
        else:
            assert srv.stats["fences"] == fences + 1
            assert srv.stats["plan_hits"] == hits
    _check_invariants(srv, reqs)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6), st.booleans())
def test_random_interleavings_preserve_invariants(seed, chunk_tokens,
                                                  prefix_share):
    """Random admit/chunk/decode/preempt/resume/EOS traces over a tight
    pool: invariants hold at every tick, the fence fires exactly when it
    must, every request completes with the stub model's predicted output,
    and the pool drains to fully free."""
    rng = random.Random(seed)
    srv = _stub_server(chunk_tokens=chunk_tokens, prefix_share=prefix_share)
    reqs, plens = [], {}
    n_reqs = rng.randint(3, 8)
    shared_prompt = np.asarray(
        [rng.randrange(VOCAB) for _ in range(6)], np.int32)

    def submit_one():
        rid = len(reqs)
        if prefix_share and rng.random() < 0.4:
            prompt = shared_prompt.copy()          # exact-duplicate traffic
        else:
            plen = rng.randint(1, 12)
            prompt = np.asarray([rng.randrange(VOCAB) for _ in range(plen)],
                                np.int32)
        max_new = rng.randint(1, 6)
        eos = None
        if rng.random() < 0.5:
            # eos the stub model will really emit at step j (or never, when
            # j >= max_new — the max_new bound must win then)
            j = rng.randint(0, 7)
            eos = (len(prompt) + j) % VOCAB
        req = Request(rid, prompt, max_new, priority=rng.choice((0, 1)),
                      eos=eos)
        plens[rid] = len(prompt)
        reqs.append(req)
        srv.submit(req)

    submit_one()
    for _ in range(rng.randint(5, 40)):
        if len(reqs) < n_reqs and rng.random() < 0.35:
            submit_one()
            _check_invariants(srv, reqs)   # submit alone must not corrupt
        else:
            _step_checked(srv, reqs)
    for _ in range(400):                   # drain, livelock-bounded
        if not (srv.queue or srv.preempted
                or any(r is not None for r in srv.slot_req)):
            break
        _step_checked(srv, reqs)
    else:
        raise AssertionError("scheduler failed to drain in 400 ticks")

    assert len(srv.completed) == len(reqs)
    assert not srv._swap and not srv._prefill_ctx
    assert srv.pt.free_pages == srv.pt.usable_pages
    for r in reqs:
        want = _expected_out(r, plens[r.rid])
        assert r.out == want, (seed, r.rid, r.out, want)
