"""Benchmark invariants: the paper-table analogues must hold structurally."""
import numpy as np
import pytest


def test_energy_proxy_traffic_ratios():
    """Operand traffic per op must scale 1:2:8 with operand bits (paper v_C)."""
    from benchmarks.energy_proxy import run
    rows = run()
    by = {r["precision"]: r for r in rows}
    assert abs(by["ternary"]["operand_bytes_per_op"]
               / by["binary"]["operand_bytes_per_op"] - 2.0) < 0.01
    assert abs(by["int8"]["operand_bytes_per_op"]
               / by["binary"]["operand_bytes_per_op"] - 8.0) < 0.01
    # roofline memory seconds ordered like the paper's energy
    assert by["binary"]["t_mem_s"] < by["ternary"]["t_mem_s"] < by["int8"]["t_mem_s"]


def test_throughput_orderings():
    """Paper: binary > ternary on the popcount path; TPU adds MXU-int8 on top."""
    from benchmarks.throughput import run
    rows = run()
    by = {r["precision"]: r for r in rows}
    assert by["binary"]["tpu_peak_gops"] > by["ternary"]["tpu_peak_gops"]
    # the documented TPU inversion: int8 MXU beats the VPU popcount paths
    assert by["int8"]["tpu_peak_gops"] > by["binary"]["tpu_peak_gops"]
    # paper's own ratio as a reference column
    assert abs(by["ternary"]["paper_gops"] / by["binary"]["paper_gops"] - 0.5) < 0.01


def test_kernel_bench_vmem_budget():
    """Resolved Tiles must fit VMEM with generous headroom — and the sweep
    is registry-driven, so every cell (incl. mixed/int4) shows up keyed by
    its OperatingPoint."""
    from benchmarks.kernel_bench import run
    rows = run()
    assert any(r["op"] and r["op"]["wprec"] == "int4" for r in rows)
    assert any(r["op"] and (r["op"]["wprec"], r["op"]["aprec"]) ==
               ("ternary", "int8") for r in rows)
    # the paged-attn decode sweep rides the same table, keyed by its
    # pseudo-cell
    assert any(r["op"] and r["op"]["wprec"] == "paged_attn" for r in rows)
    for r in rows:
        if r["vmem_tile_bytes"] is not None:
            # well under the 128 MiB VMEM
            assert r["vmem_tile_bytes"] < 16 * 2**20, r["name"]
