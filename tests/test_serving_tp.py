"""Tensor-parallel serving lockdown: the packed continuous-batching server on
a ("data", "model") mesh must be TOKEN-EXACT against the single-device server
for every W&A policy on both qgemm backends.

Why exactness is achievable (and therefore demanded): the only cross-shard
reduction the TP serve path performs is the row-parallel psum, and it runs on
the int32 accumulator BEFORE requant — integer addition is associative, so
the sharded sum equals the single-device sum bit for bit. Activation prep
runs replicated (full-K) inside shard_map, requant is elementwise, and no
float reduction axis is ever sharded. Any relaxation of that discipline
(psum after requant, partial-K activation stats, a float psum) shows up here
as a token mismatch, not a tolerance warning.

Runs in a subprocess with --xla_force_host_platform_device_count=8 (same
pattern as test_multidevice.py) so the device-count flag can't leak into the
rest of the suite. Same seeds/requests as test_serving.py.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8
from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx

MODEL = __TP__                      # TP degree; data = 8 // MODEL
mesh = jax.make_mesh((8 // MODEL, MODEL), ("data", "model"))

# same traffic as tests/test_serving.py
PROMPT_LENS, MAX_NEW, CACHE_LEN, PAGE_SIZE = (3, 9, 14), 4, 32, 4
# 24 pages: ample for this traffic AND divisible by data=2/4 so the paged
# pool really device-shards over the data axis (the default slots*8+1 pool
# is odd and would fall back to replicated); used for BOTH runs so the
# admission schedule is identical
NUM_PAGES = 24

rng = np.random.default_rng(7)

def serve(cfg, sparams, ctx, prompts, mesh_):
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=NUM_PAGES, ctx=ctx, mesh=mesh_)
    if mesh_ is not None:
        # the pool was placed per-data-shard at construction (page axis over
        # "data") while the host PageTable stays global numpy
        sh = srv.cache["first"]["k"].sharding
        assert sh.spec[0] == "data", sh
        assert isinstance(srv.pt.table, np.ndarray)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, MAX_NEW))
    srv.run()
    assert len(srv.completed) == len(prompts)
    # jit discipline survives TP: one decode signature, bucketed prefill
    assert srv.compile_counts["decode"] == 1, srv.compile_counts
    return srv

for policy in ("binary", "ternary", "int8"):
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(), policy=policy)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)
               for n in PROMPT_LENS]
    for backend in ("jnp", "pallas"):
        ctx = ModelCtx(mode="serve", backend=backend, dtype=jnp.float32)
        want = {r.rid: r.out for r in
                serve(cfg, sparams, ctx, prompts, None).completed}
        tp_srv = serve(cfg, sparams, ctx, prompts, mesh)
        got = {r.rid: r.out for r in tp_srv.completed}
        assert got == want, ("TP serve diverged", MODEL, policy, backend,
                             got, want)
        assert tp_srv.pt.free_pages == tp_srv.pt.usable_pages
        print("OK", MODEL, policy, backend, flush=True)
print("SERVING_TP_OK", MODEL)
'''


@pytest.mark.parametrize("tp", [2, 4])
def test_tp_serve_token_exact_vs_single_device(tp):
    """TP(model=2,4) x {binary,ternary,int8} x {jnp,pallas}: sharded paged
    serve == single-device serve, token for token, on a forced-8-device CPU
    mesh; pool sharded over "data", PageTable host-global."""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT.replace("__TP__", str(tp))],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    assert f"SERVING_TP_OK {tp}" in r.stdout, r.stdout[-2000:]


SCRIPT_ODD = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8
from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx

# slots=3 over data=2: the non-dividing batch that the CPU SPMD partitioner
# silently miscompiled (wrong tokens, no error) before the inert phys-slot
# padding. 3 prompts so all three slots really co-run.
mesh = jax.make_mesh((2, 4), ("data", "model"))
PROMPT_LENS, MAX_NEW, CACHE_LEN, PAGE_SIZE = (3, 9, 14), 4, 32, 4
NUM_PAGES = 24
rng = np.random.default_rng(7)
cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                          policy="ternary")
params = transformer.init(jax.random.PRNGKey(0), cfg)
sparams = transformer.pack_for_serve(params, cfg)
prompts = [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)
           for n in PROMPT_LENS]
ctx = ModelCtx(mode="serve", backend="jnp", dtype=jnp.float32)

def serve(mesh_):
    srv = Server(cfg, sparams, slots=3, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=NUM_PAGES, ctx=ctx,
                 mesh=mesh_)
    # the device batch pads to the next data-axis multiple; host-side
    # scheduling stays at 3 slots
    assert srv.phys_slots == (4 if mesh_ is not None else 3), srv.phys_slots
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, MAX_NEW))
    srv.run()
    assert len(srv.completed) == 3
    assert srv.pt.free_pages == srv.pt.usable_pages
    return {r.rid: r.out for r in srv.completed}

want = serve(None)
got = serve(mesh)
assert got == want, ("odd-slots TP serve diverged", got, want)
print("ODD_SLOTS_OK")
'''


def test_tp_odd_slots_vs_single_device():
    """slots=3 on a data=2 mesh — the divisibility regression: before the
    inert phys-slot padding, the CPU SPMD partitioner produced WRONG TOKENS
    (silently) for any slot count not dividing the data axis. Now the device
    batch pads to phys_slots=4 and the tokens must match single-device
    serving exactly."""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT_ODD],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ODD_SLOTS_OK" in r.stdout, r.stdout[-2000:]
