"""Serving-layer lockdown: continuous batching with per-slot positions and
the paged KV cache must be token-for-token identical to one-request-at-a-time
decode.

The batched-equals-sequential oracle is the test that catches the
aligned-position bug class: if the fused decode step shares one position
across slots, every slot that isn't at max(pos) rotates its query/key with
the wrong RoPE phase and writes KV at the wrong index — outputs still look
plausible, only an exact-token comparison notices. Run in f32 so both paths
compute identical algebra (row-wise ops only, so batch size cannot change
per-row results).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx

# mixed lengths spanning several prefill buckets (buckets: 4/8/16/32)
PROMPT_LENS = (3, 9, 14)
MAX_NEW = 4
CACHE_LEN = 32
PAGE_SIZE = 4


@functools.lru_cache(maxsize=None)
def _built(policy: str):
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(), policy=policy)
    sp = transformer.build_specs(cfg)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg, plane_twins=True)
    return cfg, sp, sparams


def _prompts(cfg, lens=PROMPT_LENS, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32) for n in lens]


def _greedy_reference(cfg, sp, sparams, ctx, prompt, max_new):
    """Single-request decode on the seed-validated contiguous scalar-pos path."""
    logits, cache = transformer.prefill(sparams, jnp.asarray(prompt)[None], sp,
                                        ctx, cache_len=CACHE_LEN)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(out) < max_new:
        l, cache = transformer.decode_step(
            sparams, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(pos), sp, ctx)
        out.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    return out


def _serve(cfg, sparams, ctx, prompts, *, paged, slots=2, **kw):
    srv = Server(cfg, sparams, slots=slots, cache_len=CACHE_LEN, paged=paged,
                 page_size=PAGE_SIZE, ctx=ctx, **kw)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, MAX_NEW))
    srv.run()
    assert len(srv.completed) == len(prompts)
    return srv


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("policy", ["binary", "ternary", "int8"])
def test_batched_equals_sequential(policy, backend):
    """N mixed-length requests through the paged continuous-batching server
    == single-slot sequential greedy decode, token for token, for all three
    W&A policies on both qgemm backends."""
    cfg, sp, sparams = _built(policy)
    ctx = ModelCtx(mode="serve", backend=backend, dtype=jnp.float32)
    prompts = _prompts(cfg)
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, MAX_NEW)
            for p in prompts]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (policy, backend, i, got[i], w)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_heterogeneous_policy_serves_token_exact(backend):
    """The 'het' policy assigns DIFFERENT operating points per layer class
    (s4 ffn_up next to ternary attn_out, int8 qkv) — the serve path resolves
    each layer's OperatingPoint from its own LayerQuant, not from one global
    flag pair — and the batched server must still be token-exact against the
    single-request reference."""
    cfg, sp, sparams = _built("het")
    mid = sp.mid[0] if sp.mid else sp.first  # per_class overrides first/last
    # the policy really is heterogeneous at the spec level
    assert mid.ffn.up.lq.weights.precision == "int4"
    assert mid.mixer.out.lq.weights.precision == "ternary"
    assert mid.mixer.qkv.lq.weights.precision == "int8"
    assert mid.ffn.up.lq != mid.mixer.out.lq
    # ...and each layer resolves its own registered operating point
    from repro.kernels import dispatch
    from repro.models.common import ModelCtx as _Ctx, operating_point
    ops = {nm: operating_point(s, _Ctx(mode="serve", backend=backend))
           for nm, s in (("ffn_up", mid.ffn.up), ("attn_out", mid.mixer.out))}
    assert ops["ffn_up"].key != ops["attn_out"].key
    for op in ops.values():
        dispatch.lookup(op)   # registered (would KeyError otherwise)
    ctx = ModelCtx(mode="serve", backend=backend, dtype=jnp.float32)
    prompts = _prompts(cfg)
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, MAX_NEW)
            for p in prompts]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, ("het", backend, i, got[i], w)


@pytest.mark.parametrize("policy", ["wt-a8", "w4a8"])
def test_mixed_wa_policies_serve(policy):
    """The pure mixed-cell policies (w-ternary×a-int8, w4a8) run the full
    continuous-batching path token-exactly vs the sequential reference."""
    cfg, sp, sparams = _built(policy)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompts = _prompts(cfg, lens=(3, 9), seed=13)
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, MAX_NEW)
            for p in prompts]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (policy, i, got[i], w)


def test_contiguous_matches_paged():
    """The --contiguous reference layout and the paged layout serve the same
    traffic identically (per-slot positions on both)."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompts = _prompts(cfg, lens=(2, 11, 7, 15), seed=3)
    a = _serve(cfg, sparams, ctx, prompts, paged=True)
    b = _serve(cfg, sparams, ctx, prompts, paged=False)
    assert {r.rid: r.out for r in a.completed} == {r.rid: r.out for r in b.completed}


def test_slots_decode_at_their_own_positions():
    """Requests with different prompt lengths no longer share a decode
    position: some fused tick must carry distinct per-slot positions."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    srv = _serve(cfg, sparams, ctx, _prompts(cfg, lens=(3, 14)), paged=True)
    multi = [t for t in srv.pos_trace if len(t) > 1]
    assert multi, "no tick ever decoded two slots at once"
    assert any(len(set(t.tolist())) > 1 for t in multi), \
        f"slots always shared one position: {srv.pos_trace}"


def test_jit_cache_discipline():
    """Bucketed prefill: mixed prompt lengths compile at most len(buckets)
    prefill signatures plus one decode signature."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(11)
    lens = [int(rng.integers(1, CACHE_LEN + 1)) for _ in range(10)]
    prompts = [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)
               for n in lens]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True, slots=3)
    assert srv.compile_counts["prefill"] <= len(srv.buckets), \
        (srv.compile_counts, srv.buckets)
    assert srv.compile_counts["decode"] == 1, srv.compile_counts
    total = srv.compile_counts["prefill"] + srv.compile_counts["decode"]
    assert total <= len(srv.buckets) + 1


def test_admission_is_metered_by_page_budget():
    """With a pool that can only back one request's lifetime, two queued
    requests are served one at a time even though a second slot is free —
    and every page returns to the pool at the end."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
               for _ in range(2)]
    # each request needs pages_for(min(8 + 8 - 1, 32), 4) = 4 pages; 5 usable
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=6, ctx=ctx)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, 8))
    srv.run()
    assert len(srv.completed) == 2
    assert all(len(t) == 1 for t in srv.pos_trace), \
        "page budget should have kept concurrency at 1"
    assert srv.pt.free_pages == srv.pt.usable_pages


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_windowed_arch_oracle(backend):
    """Sliding-window (local) layers: ring caches can't take padded prefill,
    so those archs bucket to the exact prompt length — and must still match
    the sequential reference through ring wraparound. Under "pallas" the
    mixed-arch model decodes with the fused paged-attn kernel on its global
    layers while the window layers keep their ring slabs (the
    `pages is not None and not window` bypass) — still token-exact."""
    cfg = dataclasses.replace(get_config("gemma3-4b").reduced(),
                              policy="ternary", window=8)   # force wraparound
    sp = transformer.build_specs(cfg)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32, backend=backend)
    prompts = _prompts(cfg, lens=(3, 13), seed=21)
    max_new = 6          # positions cross the window=8 ring boundary
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, max_new)
            for p in prompts]
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, max_new))
    srv.run()
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)


def test_submit_rejects_unservable_page_demand():
    """A request whose lifetime page demand exceeds the whole pool must be
    rejected at submit — queued, it would livelock run() forever."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=3, ctx=ctx)   # 2 usable pages
    prompt = np.arange(8, dtype=np.int32)
    with pytest.raises(ValueError):
        srv.submit(Request(0, prompt, 8))    # needs 4 pages, pool has 2
    srv.submit(Request(1, prompt[:4], 3))    # 6 tokens -> 2 pages: fits
    srv.run()
    assert len(srv.completed) == 1


def test_paged_long_decode_extends_pages():
    """A request whose decode crosses several page boundaries stays exact
    (extend-on-demand path) vs the sequential reference."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompt = _prompts(cfg, lens=(5,), seed=9)[0]
    max_new = 18     # 5 + 18 - 1 = 22 tokens -> 6 pages of 4
    want = _greedy_reference(cfg, sp, sparams, ctx, prompt, max_new)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx)
    srv.submit(Request(0, prompt, max_new))
    srv.run()
    assert srv.completed[0].out == want


# -- chunked prefill + dispatch-ahead + EOS (PR 7) ----------------------------


def test_chunked_prefill_kv_byte_identical():
    """The chunked-prefill contract at its strongest: running a prompt
    through `transformer.prefill_chunk` in C-token chunks (C NOT dividing n,
    so the padded final chunk is exercised) writes the SAME BYTES into the
    paged pool as the whole-prompt bucketed `prefill` + `scatter_prefill`
    path, and the final chunk's last-position logits are bit-identical to
    the whole-prompt last-position logits. jit-vs-jit on both sides: eager
    vs jit fuses RoPE differently (~1 ulp in K), and the server only ever
    runs the jitted calls — byte identity is claimed for what actually
    executes, not for an eager reference."""
    import jax.tree_util as jtu

    from repro.launch import kv_cache

    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    P, max_pages = PAGE_SIZE, CACHE_LEN // PAGE_SIZE
    num_pages = 1 + max_pages
    n, bucket, C = 14, 16, 5     # C does not divide n: final chunk is padded
    prompt = _prompts(cfg, lens=(n,), seed=7)[0]

    toks = np.zeros((1, bucket), np.int32)
    toks[0, :n] = prompt
    prefill_j = jax.jit(lambda p, t, lp: transformer.prefill(
        p, t, sp, ctx, cache_len=CACHE_LEN, last_pos=lp))
    logits_w, rc = prefill_j(sparams, jnp.asarray(toks),
                             jnp.asarray([n - 1], jnp.int32))
    cache_w = transformer.init_cache(cfg, 1, CACHE_LEN, paged=(num_pages, P),
                                     kv_dtype=ctx.dtype)
    pm = kv_cache.paged_leaf_mask(cfg, 1, CACHE_LEN, num_pages, P)
    ids = np.arange(1, kv_cache.pages_for(n, P) + 1, dtype=np.int32)
    pad = kv_cache.pages_for(bucket, P) - len(ids)
    sc_ids = np.concatenate([ids, np.full(pad, kv_cache.NULL_PAGE, np.int32)])
    cache_w = kv_cache.scatter_prefill(cache_w, rc, 0, paged_mask=pm,
                                       page_ids=sc_ids, page_size=P)

    cache_c = transformer.init_cache(cfg, 1, CACHE_LEN, paged=(num_pages, P),
                                     kv_dtype=ctx.dtype)
    table = np.zeros((1, max_pages), np.int32)
    table[0, :len(ids)] = ids
    step = jax.jit(lambda pr, c, t, p0, rp, wp, nr, li:
                   transformer.prefill_chunk(pr, c, t, p0, sp, ctx,
                                             read_pages=rp, write_pages=wp,
                                             nreal=nr, last_idx=li))
    covered, logits_c = 0, None
    while covered < n:
        creal = min(C, n - covered)
        ct = np.zeros((1, C), np.int32)
        ct[0, :creal] = prompt[covered:covered + creal]
        li = creal - 1 if covered + creal == n else 0
        logits_c, cache_c = step(sparams, cache_c, jnp.asarray(ct),
                                 jnp.asarray([covered], jnp.int32),
                                 jnp.asarray(table), jnp.asarray(table),
                                 jnp.asarray([creal], jnp.int32),
                                 jnp.asarray([li], jnp.int32))
        covered += creal

    compared = 0
    for (pw, aw), (_, ac), (_, ispaged) in zip(
            jtu.tree_leaves_with_path(cache_w),
            jtu.tree_leaves_with_path(cache_c),
            jtu.tree_leaves_with_path(pm)):
        if not ispaged:
            continue
        compared += 1
        if aw.ndim == 5:     # scanned mid stack: (periods, pages, P, Hk, dh)
            gw = np.asarray(aw)[:, ids].reshape(
                aw.shape[0], -1, *aw.shape[-2:])[:, :n]
            gc = np.asarray(ac)[:, ids].reshape(
                ac.shape[0], -1, *ac.shape[-2:])[:, :n]
        else:
            gw = np.asarray(aw)[ids].reshape(-1, *aw.shape[-2:])[:n]
            gc = np.asarray(ac)[ids].reshape(-1, *ac.shape[-2:])[:n]
        assert np.array_equal(gw, gc), \
            f"pool bytes diverge at {jtu.keystr(pw)}"
    assert compared > 0, "no paged leaves compared — mask/layout changed?"
    assert np.array_equal(np.asarray(logits_w[0, -1]),
                          np.asarray(logits_c[0, 0]))


@pytest.mark.parametrize("dispatch_ahead", [True, False])
def test_chunked_serve_matches_sequential(dispatch_ahead):
    """Mixed-length traffic through the server with --chunk-tokens (chunk
    size NOT dividing the prompt lengths) == sequential greedy reference,
    token for token, with and without dispatch-ahead double buffering. The
    jit budget collapses to {chunk, decode}: no prefill bucket signatures."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompts = _prompts(cfg)
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, MAX_NEW)
            for p in prompts]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True, chunk_tokens=5,
                 dispatch_ahead=dispatch_ahead)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (dispatch_ahead, i, got[i], w)
    assert srv.stats["chunk_ticks"] > 0
    assert srv.compile_counts["prefill"] == 0, srv.compile_counts
    assert srv.compile_counts["chunk"] == 1, srv.compile_counts
    assert srv.compile_counts["decode"] == 1, srv.compile_counts
    if dispatch_ahead:
        assert srv.stats["plan_hits"] > 0, srv.stats
    assert srv.pt.free_pages == srv.pt.usable_pages


def test_eos_retires_slot_and_frees_pages():
    """EOS retirement: a request stops the very step its eos token is
    sampled — output truncated at the EOS, the slot's pages back in the pool
    that same tick, and later ticks neither sample nor write KV for it
    (pos_trace stops growing once the server is drained). A co-running
    request without eos is unaffected."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    p_eos, p_other = _prompts(cfg, lens=(5, 9), seed=17)
    max_new = 6
    ref_eos = _greedy_reference(cfg, sp, sparams, ctx, p_eos, max_new)
    ref_other = _greedy_reference(cfg, sp, sparams, ctx, p_other, max_new)
    eos_tok = ref_eos[2]               # retire after the 3rd sampled token...
    k = ref_eos.index(eos_tok)         # ...or wherever it first appears
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx)
    req = Request(0, p_eos, max_new, eos=eos_tok)
    srv.submit(req)
    srv.submit(Request(1, p_other, max_new))
    while not req.done:
        srv.step()
    assert req.out == ref_eos[:k + 1]
    # pages freed the retire tick, not at drain (slot 1 still holds its own)
    assert srv.pt.held[0] == 0
    out_after_eos = list(req.out)
    srv.run()
    assert req.out == out_after_eos, "sampled past EOS"
    got = {r.rid: r.out for r in srv.completed}
    assert got[1] == ref_other
    assert srv.pt.free_pages == srv.pt.usable_pages
    # once drained, extra steps dispatch nothing (no KV writes, no samples)
    ticks = len(srv.pos_trace)
    for _ in range(3):
        assert srv.step() is False
    assert len(srv.pos_trace) == ticks


def test_byte_tokenizer_roundtrip_and_eos_serves():
    """data.tokenizer.ByteTokenizer: exact text round-trip, ids fit the
    reduced vocab, and an encoded prompt serves through the full path with
    Request.eos = ByteTokenizer.EOS wired up."""
    from repro.data.tokenizer import ByteTokenizer

    cfg, sp, sparams = _built("ternary")
    tok = ByteTokenizer(vocab=cfg.vocab)
    text = "BrainTTA: 35 fJ/op — ñaé"
    ids = tok.encode(text, eos=True)
    assert ids[0] == ByteTokenizer.BOS and ids[-1] == ByteTokenizer.EOS
    assert ids.max() < cfg.vocab
    assert tok.decode(ids) == text
    with pytest.raises(ValueError):
        ByteTokenizer(vocab=128)
    prompt = tok.encode("hi", eos=False)[:8].astype(np.int32)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    want = _greedy_reference(cfg, sp, sparams, ctx, prompt, MAX_NEW)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx)
    srv.submit(Request(0, prompt, MAX_NEW, eos=ByteTokenizer.EOS))
    srv.run()
    stop = (want.index(ByteTokenizer.EOS) + 1
            if ByteTokenizer.EOS in want else MAX_NEW)
    assert srv.completed[0].out == want[:stop]


def test_jit_counters_are_signature_exact():
    """compile_counts counts DISTINCT abstract signatures, not call-site
    traces: jax.clear_caches() forces a re-trace of already-seen signatures
    and must NOT inflate any counter, while a genuinely new prompt bucket
    afterwards must raise the prefill count by exactly one."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx)
    prompts = _prompts(cfg, lens=(3, 9), seed=23)   # buckets 4 and 16
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, 2))
    srv.run()
    before = dict(srv.compile_counts)
    assert before["prefill"] == 2 and before["decode"] == 1, before
    jax.clear_caches()          # evict every XLA executable: forced re-trace
    for i, p in enumerate(prompts):
        srv.submit(Request(10 + i, p, 2))
    srv.run()
    assert dict(srv.compile_counts) == before, \
        (srv.compile_counts, before, "re-trace of a seen signature counted")
    # a new bucket (len 5 -> bucket 8) is a genuinely new signature: +1
    srv.submit(Request(20, _prompts(cfg, lens=(5,), seed=29)[0], 2))
    srv.run()
    assert srv.compile_counts["prefill"] == before["prefill"] + 1
    assert srv.compile_counts["decode"] == before["decode"]


# -- self-speculative decoding + EOS truncation (PR 8) ------------------------


def test_retire_truncates_mid_batch_eos():
    """Regression for the `out[-1] == eos` retire test: a multi-token accept
    can land tokens PAST the stop token in one tick. _retire must truncate
    req.out at the FIRST EOS and retire the slot that same tick (pages
    freed), never letting post-EOS tokens survive or the slot keep
    decoding."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompt = _prompts(cfg, lens=(5,), seed=31)[0]
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx)
    req = Request(0, prompt, 8)
    srv.submit(req)
    srv.step()                       # admitted + first tokens sampled
    s = srv.slot_req.index(req)
    eos = int(max(req.out)) + 1      # a token the request never sampled
    req.eos = eos
    # simulate a speculative tick that emitted [x, EOS, y, z] at once
    head = list(req.out)
    req.out.extend([eos, 7, 9])
    srv.slot_pos[s] += 3
    srv._retire()
    assert req.done
    assert req.out == head + [eos], req.out
    assert srv.pt.held[s] == 0, "pages not freed on mid-batch EOS retire"


@pytest.mark.parametrize("policy", ["binary", "ternary", "int8", "w4a8"])
def test_spec_serving_matches_sequential(policy):
    """Self-speculative decoding (sign-plane draft, full-precision verify)
    is TOKEN-EXACT vs the sequential greedy oracle for every policy class —
    plane-composed draft cells where they exist (w4a8), per-layer popcount
    fallback elsewhere — with exactly one draft and one verify signature."""
    cfg, sp, sparams = _built(policy)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompts = _prompts(cfg)
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, MAX_NEW)
            for p in prompts]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True,
                 spec_draft="planes:1", spec_k=3)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (policy, i, got[i], w)
    assert srv.stats["spec_ticks"] > 0
    assert srv.compile_counts["draft"] == 1, srv.compile_counts
    assert srv.compile_counts["verify"] == 1, srv.compile_counts
    assert srv.pt.free_pages == srv.pt.usable_pages


def test_spec_serving_with_prefix_share_and_preempt():
    """Speculation composes with the full scheduler: prefix-shared prompts
    (CoW forks must cover the whole lookahead write range) and a pool tight
    enough to preempt mid-decode — still token-exact, and the swap images
    survive coverage extended past the decode position (the _preempt trim).
    Request 1 duplicates request 0 exactly, so the co-running pair shares
    its boundary page and must fork before draft/verify scribble in it."""
    cfg, sp, sparams = _built("w4a8")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(41)
    prefix = rng.integers(0, cfg.vocab, size=(PAGE_SIZE,)).astype(np.int32)
    mk = lambda n: np.concatenate(
        [prefix, rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)])
    p0 = mk(5)
    prompts = [p0, p0.copy(), mk(3), mk(7)]
    max_new = 6
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, max_new)
            for p in prompts]
    srv = Server(cfg, sparams, slots=3, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=11, ctx=ctx,
                 prefix_share=True, preempt=True,
                 spec_draft="planes:2", spec_k=4)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, max_new))
    srv.run()
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)
    assert srv.stats["shared_pages"] > 0, srv.stats
    assert srv.stats["cow_forks"] > 0, srv.stats
    assert srv.pt.free_pages == srv.pt.usable_pages


def test_spec_serving_eos_stops_inside_window():
    """An EOS sampled inside the speculative window retires the request with
    its output truncated exactly where the sequential oracle stops — accepted
    tokens past the stop token must not leak into req.out."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompt = _prompts(cfg, lens=(5,), seed=17)[0]
    max_new = 6
    ref = _greedy_reference(cfg, sp, sparams, ctx, prompt, max_new)
    eos_tok = ref[2]
    k = ref.index(eos_tok)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx,
                 spec_draft="planes:1", spec_k=4)
    srv.submit(Request(0, prompt, max_new, eos=eos_tok))
    srv.run()
    assert srv.completed[0].out == ref[:k + 1], \
        (srv.completed[0].out, ref, k)
    assert srv.pt.free_pages == srv.pt.usable_pages


def test_spec_falls_back_where_verify_cannot_be_exact():
    """Archs that cannot replay a multi-token range exactly (window/recurrent
    state) silently fall back to sequential decoding instead of serving
    wrong tokens — and stay token-exact."""
    cfg = dataclasses.replace(get_config("gemma3-4b").reduced(),
                              policy="ternary", window=8)
    sp = transformer.build_specs(cfg)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompt = _prompts(cfg, lens=(9,), seed=21)[0]
    want = _greedy_reference(cfg, sp, sparams, ctx, prompt, MAX_NEW)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx,
                 spec_draft="planes:1", spec_k=4)
    assert not srv.spec
    srv.submit(Request(0, prompt, MAX_NEW))
    srv.run()
    assert srv.completed[0].out == want
    assert srv.stats["spec_ticks"] == 0
