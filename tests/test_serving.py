"""Serving-layer lockdown: continuous batching with per-slot positions and
the paged KV cache must be token-for-token identical to one-request-at-a-time
decode.

The batched-equals-sequential oracle is the test that catches the
aligned-position bug class: if the fused decode step shares one position
across slots, every slot that isn't at max(pos) rotates its query/key with
the wrong RoPE phase and writes KV at the wrong index — outputs still look
plausible, only an exact-token comparison notices. Run in f32 so both paths
compute identical algebra (row-wise ops only, so batch size cannot change
per-row results).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx

# mixed lengths spanning several prefill buckets (buckets: 4/8/16/32)
PROMPT_LENS = (3, 9, 14)
MAX_NEW = 4
CACHE_LEN = 32
PAGE_SIZE = 4


@functools.lru_cache(maxsize=None)
def _built(policy: str):
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(), policy=policy)
    sp = transformer.build_specs(cfg)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    return cfg, sp, sparams


def _prompts(cfg, lens=PROMPT_LENS, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32) for n in lens]


def _greedy_reference(cfg, sp, sparams, ctx, prompt, max_new):
    """Single-request decode on the seed-validated contiguous scalar-pos path."""
    logits, cache = transformer.prefill(sparams, jnp.asarray(prompt)[None], sp,
                                        ctx, cache_len=CACHE_LEN)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(out) < max_new:
        l, cache = transformer.decode_step(
            sparams, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(pos), sp, ctx)
        out.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    return out


def _serve(cfg, sparams, ctx, prompts, *, paged, slots=2, **kw):
    srv = Server(cfg, sparams, slots=slots, cache_len=CACHE_LEN, paged=paged,
                 page_size=PAGE_SIZE, ctx=ctx, **kw)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, MAX_NEW))
    srv.run()
    assert len(srv.completed) == len(prompts)
    return srv


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("policy", ["binary", "ternary", "int8"])
def test_batched_equals_sequential(policy, backend):
    """N mixed-length requests through the paged continuous-batching server
    == single-slot sequential greedy decode, token for token, for all three
    W&A policies on both qgemm backends."""
    cfg, sp, sparams = _built(policy)
    ctx = ModelCtx(mode="serve", backend=backend, dtype=jnp.float32)
    prompts = _prompts(cfg)
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, MAX_NEW)
            for p in prompts]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (policy, backend, i, got[i], w)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_heterogeneous_policy_serves_token_exact(backend):
    """The 'het' policy assigns DIFFERENT operating points per layer class
    (s4 ffn_up next to ternary attn_out, int8 qkv) — the serve path resolves
    each layer's OperatingPoint from its own LayerQuant, not from one global
    flag pair — and the batched server must still be token-exact against the
    single-request reference."""
    cfg, sp, sparams = _built("het")
    mid = sp.mid[0] if sp.mid else sp.first  # per_class overrides first/last
    # the policy really is heterogeneous at the spec level
    assert mid.ffn.up.lq.weights.precision == "int4"
    assert mid.mixer.out.lq.weights.precision == "ternary"
    assert mid.mixer.qkv.lq.weights.precision == "int8"
    assert mid.ffn.up.lq != mid.mixer.out.lq
    # ...and each layer resolves its own registered operating point
    from repro.kernels import dispatch
    from repro.models.common import ModelCtx as _Ctx, operating_point
    ops = {nm: operating_point(s, _Ctx(mode="serve", backend=backend))
           for nm, s in (("ffn_up", mid.ffn.up), ("attn_out", mid.mixer.out))}
    assert ops["ffn_up"].key != ops["attn_out"].key
    for op in ops.values():
        dispatch.lookup(op)   # registered (would KeyError otherwise)
    ctx = ModelCtx(mode="serve", backend=backend, dtype=jnp.float32)
    prompts = _prompts(cfg)
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, MAX_NEW)
            for p in prompts]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, ("het", backend, i, got[i], w)


@pytest.mark.parametrize("policy", ["wt-a8", "w4a8"])
def test_mixed_wa_policies_serve(policy):
    """The pure mixed-cell policies (w-ternary×a-int8, w4a8) run the full
    continuous-batching path token-exactly vs the sequential reference."""
    cfg, sp, sparams = _built(policy)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompts = _prompts(cfg, lens=(3, 9), seed=13)
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, MAX_NEW)
            for p in prompts]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True)
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (policy, i, got[i], w)


def test_contiguous_matches_paged():
    """The --contiguous reference layout and the paged layout serve the same
    traffic identically (per-slot positions on both)."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompts = _prompts(cfg, lens=(2, 11, 7, 15), seed=3)
    a = _serve(cfg, sparams, ctx, prompts, paged=True)
    b = _serve(cfg, sparams, ctx, prompts, paged=False)
    assert {r.rid: r.out for r in a.completed} == {r.rid: r.out for r in b.completed}


def test_slots_decode_at_their_own_positions():
    """Requests with different prompt lengths no longer share a decode
    position: some fused tick must carry distinct per-slot positions."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    srv = _serve(cfg, sparams, ctx, _prompts(cfg, lens=(3, 14)), paged=True)
    multi = [t for t in srv.pos_trace if len(t) > 1]
    assert multi, "no tick ever decoded two slots at once"
    assert any(len(set(t.tolist())) > 1 for t in multi), \
        f"slots always shared one position: {srv.pos_trace}"


def test_jit_cache_discipline():
    """Bucketed prefill: mixed prompt lengths compile at most len(buckets)
    prefill signatures plus one decode signature."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(11)
    lens = [int(rng.integers(1, CACHE_LEN + 1)) for _ in range(10)]
    prompts = [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)
               for n in lens]
    srv = _serve(cfg, sparams, ctx, prompts, paged=True, slots=3)
    assert srv.compile_counts["prefill"] <= len(srv.buckets), \
        (srv.compile_counts, srv.buckets)
    assert srv.compile_counts["decode"] == 1, srv.compile_counts
    total = srv.compile_counts["prefill"] + srv.compile_counts["decode"]
    assert total <= len(srv.buckets) + 1


def test_admission_is_metered_by_page_budget():
    """With a pool that can only back one request's lifetime, two queued
    requests are served one at a time even though a second slot is free —
    and every page returns to the pool at the end."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
               for _ in range(2)]
    # each request needs pages_for(min(8 + 8 - 1, 32), 4) = 4 pages; 5 usable
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=6, ctx=ctx)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, 8))
    srv.run()
    assert len(srv.completed) == 2
    assert all(len(t) == 1 for t in srv.pos_trace), \
        "page budget should have kept concurrency at 1"
    assert srv.pt.free_pages == srv.pt.usable_pages


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_windowed_arch_oracle(backend):
    """Sliding-window (local) layers: ring caches can't take padded prefill,
    so those archs bucket to the exact prompt length — and must still match
    the sequential reference through ring wraparound. Under "pallas" the
    mixed-arch model decodes with the fused paged-attn kernel on its global
    layers while the window layers keep their ring slabs (the
    `pages is not None and not window` bypass) — still token-exact."""
    cfg = dataclasses.replace(get_config("gemma3-4b").reduced(),
                              policy="ternary", window=8)   # force wraparound
    sp = transformer.build_specs(cfg)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    ctx = ModelCtx(mode="serve", dtype=jnp.float32, backend=backend)
    prompts = _prompts(cfg, lens=(3, 13), seed=21)
    max_new = 6          # positions cross the window=8 ring boundary
    want = [_greedy_reference(cfg, sp, sparams, ctx, p, max_new)
            for p in prompts]
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx)
    for i, p in enumerate(prompts):
        srv.submit(Request(i, p, max_new))
    srv.run()
    got = {r.rid: r.out for r in srv.completed}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)


def test_submit_rejects_unservable_page_demand():
    """A request whose lifetime page demand exceeds the whole pool must be
    rejected at submit — queued, it would livelock run() forever."""
    cfg, _, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, num_pages=3, ctx=ctx)   # 2 usable pages
    prompt = np.arange(8, dtype=np.int32)
    with pytest.raises(ValueError):
        srv.submit(Request(0, prompt, 8))    # needs 4 pages, pool has 2
    srv.submit(Request(1, prompt[:4], 3))    # 6 tokens -> 2 pages: fits
    srv.run()
    assert len(srv.completed) == 1


def test_paged_long_decode_extends_pages():
    """A request whose decode crosses several page boundaries stays exact
    (extend-on-demand path) vs the sequential reference."""
    cfg, sp, sparams = _built("ternary")
    ctx = ModelCtx(mode="serve", dtype=jnp.float32)
    prompt = _prompts(cfg, lens=(5,), seed=9)[0]
    max_new = 18     # 5 + 18 - 1 = 22 tokens -> 6 pages of 4
    want = _greedy_reference(cfg, sp, sparams, ctx, prompt, max_new)
    srv = Server(cfg, sparams, slots=2, cache_len=CACHE_LEN, paged=True,
                 page_size=PAGE_SIZE, ctx=ctx)
    srv.submit(Request(0, prompt, max_new))
    srv.run()
    assert srv.completed[0].out == want
