"""hypothesis, or a minimal deterministic fallback when it isn't installed.

The container image may lack `hypothesis` (it is listed in
requirements-dev.txt). Property tests import `given`/`settings`/`st` from
here; with real hypothesis present this module is a pass-through. The
fallback draws `max_examples` deterministic samples per strategy (seeded
RNG, plus the strategy's boundary values) and runs the test body once per
draw — weaker than real shrinking/search, but it keeps every property
exercised instead of skipping five test modules wholesale.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

except ImportError:
    import functools
    import random

    class _Strategy:
        def __init__(self, draw, boundary=()):
            self._draw = draw
            self._boundary = tuple(boundary)

        def example(self, rng, i):
            if i < len(self._boundary):
                return self._boundary[i]
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)),
                             tuple(fn(b) for b in self._boundary))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value),
                             (min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: rng.choice(seq), seq)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)),
                             (False, True))

    st = _Strategies()

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # @settings may sit under OR over @given (hypothesis allows
                # both): check the wrapper itself first (outermost order
                # tags it after we return), then the wrapped fn
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                rng = random.Random(0xB2A117A)  # deterministic across runs
                for i in range(n):
                    fn(*args, *(s.example(rng, i) for s in strategies),
                       **kwargs)
            # pytest must not see the strategy params as fixtures: drop the
            # functools.wraps back-pointer so inspect.signature stops here
            del wrapper.__wrapped__
            return wrapper
        return deco
