"""Serve-path equivalences: popcount vs MXU formulations at model level, and
the precision-policy footprint ladder (the paper's Table I memory column)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import registry, transformer
from repro.models.common import ModelCtx


def _packed_bytes(cfg):
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(sparams))


def test_policy_footprint_ladder():
    """binary < ternary < int8 < none packed footprint (paper Table I)."""
    base = get_config("llama3.2-3b").reduced()
    sizes = {}
    for pol in ("binary", "ternary", "w-int8", "none"):
        sizes[pol] = _packed_bytes(dataclasses.replace(base, policy=pol))
    assert sizes["binary"] < sizes["ternary"] < sizes["w-int8"] < sizes["none"]
    # bit ratios: ternary ~2x binary planes; int8 ~8x binary (+ scales/embeds)
    assert sizes["none"] / sizes["binary"] > 3.0


@pytest.mark.parametrize("impl", ["popcount", "mxu"])
def test_full_wa_serve_impls_agree(impl):
    """W&A ternary serve: popcount and MXU formulations give the same logits."""
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(), policy="ternary")
    sp = transformer.build_specs(cfg)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    outs = {}
    for i in ("popcount", "mxu"):
        ctx = ModelCtx(mode="serve", impl=i, dtype=jnp.float32)
        logits, _ = transformer.prefill(sparams, tokens, sp, ctx)
        outs[i] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["popcount"], outs["mxu"], rtol=2e-2, atol=2e-2)


def test_int8_cache_vs_bf16_cache_quality():
    """int8 KV cache decode stays within quantization tolerance of bf16."""
    base = get_config("llama3.2-3b").reduced()
    sp = transformer.build_specs(base)
    params = transformer.init(jax.random.PRNGKey(0), base)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, base.vocab)
    ref = None
    for cd in ("bfloat16", "int8"):
        cfg = dataclasses.replace(base, kv_cache_dtype=cd)
        spc = transformer.build_specs(cfg)
        ctx = ModelCtx(mode="train", dtype=jnp.float32)
        _, cache = transformer.prefill(params, tokens[:, :16], spc, ctx,
                                       cache_len=20)
        ld, _ = transformer.decode_step(params, cache, tokens[:, 16:17],
                                        jnp.int32(16), spc, ctx)
        if ref is None:
            ref = np.asarray(ld)
        else:
            corr = np.corrcoef(np.asarray(ld).ravel(), ref.ravel())[0, 1]
            assert corr > 0.995, corr


def test_pallas_backend_e2e_matches_jnp():
    """Full serve prefill through the Pallas backend (flash attention +
    packed/weight-only GEMM dispatch) == the jnp backend, exactly in f32."""
    cfg = get_config("llama3.2-3b").reduced()
    sp = transformer.build_specs(cfg)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 256), 0, cfg.vocab)
    outs = {}
    for backend in ("jnp", "pallas"):
        ctx = ModelCtx(mode="serve", backend=backend, dtype=jnp.float32)
        logits, _ = transformer.prefill(sparams, tokens, sp, ctx)
        outs[backend] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["pallas"], outs["jnp"], rtol=1e-4, atol=1e-4)
