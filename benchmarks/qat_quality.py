"""§II-A motivation: mixed precision exists because quantization costs
accuracy unevenly across layers. QAT loss curves per policy on the synthetic
LM (learnable motif structure): fp32 < int8 <~ mixed < ternary < binary —
with `mixed` (int8 first/last + ternary body, the paper's recipe) recovering
most of the pure-ternary gap.
"""
from __future__ import annotations

import dataclasses

import numpy as np

POLICIES = ("none", "int8", "mixed", "ternary", "binary")


def run(steps: int = 60, arch: str = "llama3.2-3b") -> dict[str, list[float]]:
    from repro.launch import train as train_mod
    out = {}
    for pol in POLICIES:
        losses = train_mod.main([
            "--arch", arch, "--reduced", "--steps", str(steps),
            "--batch", "8", "--seq", "64", "--lr", "3e-3",
            "--policy", pol, "--layers", "6",   # body layers exist -> the
            "--log-every", "1000000"])          # body precision matters
        out[pol] = losses
    return out


def main(steps: int = 60):
    curves = run(steps)
    print("# qat_quality (per-policy final train loss; paper §II-A motivation)")
    print("policy,first5_loss,final5_loss,drop")
    for pol, ls in curves.items():
        f, l = float(np.mean(ls[:5])), float(np.mean(ls[-5:]))
        print(f"{pol},{f:.4f},{l:.4f},{f-l:.4f}")
    return curves


if __name__ == "__main__":
    main()
