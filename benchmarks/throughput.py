"""Table I analogue: peak throughput per precision.

BrainTTA: 614/307/77 GOPS (binary/ternary/int8) at 300 MHz — the 2:1 binary:
ternary and 8:1 binary:int8 ratios come from the fixed 1024-bit datapath
(v_C = 32/16/4 operands per word).

TPU v5e mapping (DESIGN.md §2): binary/ternary MACs ride the VPU via
XNOR/gated-XNOR+popcount; int8 rides the MXU natively. On the MXU-dominant
TPU the ordering *inverts* for compute (int8 fastest), while the *traffic*
ordering still follows the paper (binary cheapest). Both are reported; the
CPU wall-clock column validates the packed formulations actually run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack
from repro.launch.mesh import PEAK_OPS_INT8

VPU_OPS = 4e12

M, K, N = 256, 4096, 512   # bench GEMM
MACS = M * K * N
OPS = 2 * MACS


def _bench(f, *args, iters=3):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters


def run() -> list[dict]:
    rng = np.random.default_rng(1)
    rows = []

    x = jnp.asarray(np.sign(rng.standard_normal((M, K))) + 0.0)
    w = jnp.asarray(np.sign(rng.standard_normal((N, K))) + 0.0)
    xp, wp = pack.pack_binary(x), pack.pack_binary(w)
    dt = _bench(jax.jit(lambda a, b: pack.binary_dot_words(a[:, None, :], b, K)),
                xp, wp)
    rows.append(dict(precision="binary",
                     tpu_peak_gops=(32 / 3) * VPU_OPS * 2 / 1e9,
                     cpu_gops=OPS / dt / 1e9, paper_gops=614.0))

    xt = jnp.asarray(rng.integers(-1, 2, (M, K)).astype(np.float32))
    wt = jnp.asarray(rng.integers(-1, 2, (N, K)).astype(np.float32))
    xm, xs = pack.pack_ternary(xt)
    wm, ws = pack.pack_ternary(wt)
    dt = _bench(jax.jit(lambda a, b, c, d: pack.ternary_dot_words(
        a[:, None, :], b[:, None, :], c, d)), xm, xs, wm, ws)
    rows.append(dict(precision="ternary",
                     tpu_peak_gops=(32 / 5) * VPU_OPS * 2 / 1e9,
                     cpu_gops=OPS / dt / 1e9, paper_gops=307.0))

    xq = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    dt = _bench(jax.jit(lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)), xq, wq)
    rows.append(dict(precision="int8",
                     tpu_peak_gops=PEAK_OPS_INT8 / 1e9,
                     cpu_gops=OPS / dt / 1e9, paper_gops=77.0))
    return rows


def main():
    rows = run()
    print("# throughput (paper Table I: 614/307/77 GOPS b/t/i8)")
    print("precision,paper_gops,tpu_model_gops,cpu_measured_gops,paper_ratio,tpu_ratio")
    base_p, base_t = rows[0]["paper_gops"], rows[0]["tpu_peak_gops"]
    for r in rows:
        print(f"{r['precision']},{r['paper_gops']:.0f},{r['tpu_peak_gops']:.0f},"
              f"{r['cpu_gops']:.2f},{r['paper_gops']/base_p:.2f},"
              f"{r['tpu_peak_gops']/base_t:.2f}")
    return rows


if __name__ == "__main__":
    main()
