"""Dispatch-registry micro-bench: interpret-mode wall time (correctness-scale)
+ the analytic TPU tile model, for EVERY registered operating point.

Driven by the `repro.kernels.dispatch` registry: each cell is benched through
the single `qgemm` entry point keyed by its `OperatingPoint` (so the bench
exercises exactly the code the serve stack runs — activation prep, padding,
TuneTable tile resolution, fused bias epilogue and all). Cells with a Pallas
MacBody run on the pallas backend; weight-only/dense cells run their jnp
formulation. Registering a new precision/kernel variant adds a bench row
automatically.

Wall time in interpret mode is NOT TPU performance — it validates the
kernels execute and lets us compare formulations structurally. The derived
column is the VMEM working set of the resolved tile (must be << 128 MiB),
from `harness.vmem_tile_bytes`.

Outputs:
  * a CSV-ish table on stdout (the `benchmarks.run` report format)
  * `BENCH_dispatch.json` — the machine-readable per-operating-point
    baseline the perf trajectory tracks across PRs (--out to relocate)
  * `--retune` — sweep candidate `Tile`s per cell and rewrite the shipped
    `kernels/tune_cpu.json` TuneTable (the "autotune per operating point"
    data file; rerun on real TPU hardware with interpret off)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.core.precision import LayerQuant
from repro.core.quantize import QuantSpec
from repro.kernels import dispatch, harness
from repro.kernels.dispatch import OperatingPoint, Tile, TuneTable

M, K, N = 128, 1024, 128


def _cell_problem(cell, seed=0):
    spec = qlinear.QLinearSpec(
        K, N, LayerQuant(QuantSpec(cell.wprec), QuantSpec(cell.aprec)))
    p = qlinear.pack_params(
        qlinear.init(jax.random.PRNGKey(seed), spec), spec)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K)) * 0.2
    return spec, p, x


def _cell_op(cell, tile: Tile | None = None) -> OperatingPoint:
    impl = "popcount" if cell.impl == "*" else cell.impl
    backend = "pallas" if cell.body is not None else "jnp"
    return OperatingPoint(cell.wprec, cell.aprec, impl, backend, tile=tile)


def _time_us(fn, reps: int = 3) -> float:
    jax.block_until_ready(fn())                       # compile outside timing
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run():
    rows = []
    for key in sorted(dispatch.cells()):
        cell = dispatch.cells()[key]
        spec, p, x = _cell_problem(cell)
        op = _cell_op(cell)
        us = _time_us(lambda: dispatch.qgemm(p, x, spec, op))
        tile = op.tile or dispatch.default_tune().tile_for(op) or Tile()
        vmem = (harness.vmem_tile_bytes(cell.body, tile)
                if cell.body is not None else None)
        rows.append({
            "op": {"wprec": op.wprec, "aprec": op.aprec, "impl": op.impl,
                   "backend": op.backend},
            "name": cell.body.name if cell.body is not None else cell.tag,
            "us_per_call": round(us, 1),
            "tile": {"bm": tile.bm, "bn": tile.bn, "bkq": tile.bkq},
            "vmem_tile_bytes": vmem,
        })

    from repro.kernels.flash_attn import flash_attention
    ks3 = jax.random.split(jax.random.PRNGKey(3), 3)
    qf = jax.random.normal(ks3[0], (4, 256, 64), jnp.float32)
    kf = jax.random.normal(ks3[1], (2, 256, 64), jnp.float32)
    vf = jax.random.normal(ks3[2], (2, 256, 64), jnp.float32)
    fa_us = _time_us(lambda: flash_attention(qf, kf, vf, causal=True,
                                             bq=128, bk=128))
    rows.append({"op": None, "name": "flash_attn",
                 "us_per_call": round(fa_us, 1), "tile": None,
                 "vmem_tile_bytes": 128 * 64 * 4 * 2 + 128 * 64 * 4 + 2 * 128 * 4})
    rows.extend(_paged_attn_rows())
    return rows


# decode-attention geometry for the paged-attn sweep/retune: the reduced-
# llama serve head shape over `slots` continuous-batching rows
PA_SLOTS, PA_HK, PA_HQ, PA_DH = 4, 2, 4, 32


def _paged_attn_problem(page_size: int, table_pages: int, active: int,
                        seed: int = 5):
    """Int8 pool + fully-provisioned disjoint page tables + a uniform active
    length: the paged_flash_decode bench unit (pool size x active length x
    page size)."""
    import numpy as np
    num_pages = 1 + PA_SLOTS * table_pages
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (PA_SLOTS, PA_HQ, PA_DH), jnp.float32)
    kp = jax.random.randint(ks[1], (num_pages, page_size, PA_HK, PA_DH),
                            -127, 128, jnp.int8)
    vp = jax.random.randint(ks[2], (num_pages, page_size, PA_HK, PA_DH),
                            -127, 128, jnp.int8)
    pages = jnp.asarray(np.stack(
        [1 + r * table_pages + np.arange(table_pages)
         for r in range(PA_SLOTS)]).astype(np.int32))
    pos = jnp.full((PA_SLOTS,), active - 1, jnp.int32)
    return q, kp, vp, pages, pos


def _paged_attn_rows():
    """Sweep the paged-attn decode kernel like the qgemm cells: rows keyed by
    its TuneTable pseudo-cell, one per (pool size x active length x page
    size) point. The active-length column is where the in-kernel early bound
    shows up (same provisioned table, shorter walk)."""
    from repro.kernels import paged_attn as pa
    bkp = pa.resolve_pages_per_block()
    rows = []
    for page_size, table_pages, active in [
        (16, 64, 256), (16, 64, 1024),          # 1k-token pool, small pages
        (64, 64, 1024), (64, 64, 4096),         # 4k-token pool
        (64, 128, 4096),                        # 8k-token pool, half active
    ]:
        q, kp, vp, pages, pos = _paged_attn_problem(page_size, table_pages,
                                                    active)
        us = _time_us(lambda: pa.paged_flash_decode(
            q, kp, vp, pages, pos, pages_per_block=bkp,
            interpret=dispatch.INTERPRET))
        rows.append({
            "op": {"wprec": "paged_attn", "aprec": "decode", "impl": "*",
                   "backend": "pallas"},
            "name": f"paged_attn P{page_size}xT{table_pages}@a{active}",
            "us_per_call": round(us, 1),
            "tile": {"bm": 1, "bn": 1, "bkq": bkp},
            "vmem_tile_bytes": pa.vmem_decode_tile_bytes(
                page_size, PA_HK, PA_DH, PA_HQ, bkp, kv_bytes=1),
        })
    return rows


def retune(out_path: str, reps: int = 2) -> TuneTable:
    """Sweep candidate Tiles per Pallas cell, keep the fastest, save a
    TuneTable. Interpret-mode-on-CPU numbers — a structural baseline; rerun
    with REPRO_PALLAS_INTERPRET=0 on real hardware for production tables."""
    tiles: dict[tuple, Tile] = {}
    for key in sorted(dispatch.cells()):
        cell = dispatch.cells()[key]
        if cell.body is None:
            continue
        spec, p, x = _cell_problem(cell)
        dflt = cell.body.default_bkq
        candidates = [Tile(128, 128, dflt), Tile(64, 128, dflt),
                      Tile(128, 128, max(dflt // 2, 1)),
                      Tile(128, 128, dflt * 2)]
        best, best_us = None, float("inf")
        for tile in candidates:
            op = _cell_op(cell, tile=tile)
            us = _time_us(lambda: dispatch.qgemm(p, x, spec, op), reps=reps)
            if us < best_us:
                best, best_us = tile, us
        tiles[cell.key] = best
        print(f"  {cell.tag:24s} -> bm={best.bm} bn={best.bn} "
              f"bkq={best.bkq} ({best_us:.0f}us)")

    # paged-attn pseudo-cell: bkq = pages per kv block of the decode page
    # walk (bm/bn unused). Representative point: 4k-token pool, 1k active.
    from repro.kernels import paged_attn as pa
    q, kp, vp, pages, pos = _paged_attn_problem(64, 64, 1024)
    best_bkp, best_us = None, float("inf")
    for bkp in (1, 2, 4, 8):
        us = _time_us(lambda: pa.paged_flash_decode(
            q, kp, vp, pages, pos, pages_per_block=bkp,
            interpret=dispatch.INTERPRET), reps=reps)
        if us < best_us:
            best_bkp, best_us = bkp, us
    tiles[pa.TUNE_KEY] = Tile(1, 1, best_bkp)
    print(f"  {'paged_attn/decode/*':24s} -> bkq={best_bkp} "
          f"(pages/block, {best_us:.0f}us)")
    # carry rows the sweep didn't remeasure (e.g. a real-TPU table's wildcard
    # entries), then prune keys no registered cell can resolve anymore —
    # renamed impls / retired precision pairs must not ride along forever
    import os
    if os.path.exists(out_path):
        for key, tile in TuneTable.load(out_path).tiles.items():
            tiles.setdefault(key, tile)
    tiles, dropped = dispatch.prune_stale_tiles(tiles,
                                                extra_keys=(pa.TUNE_KEY,))
    for key in dropped:
        print(f"  pruned stale row {'/'.join(key)} (no registered cell)")
    table = TuneTable(
        tiles=tiles,
        source=f"kernel_bench --retune: interpret-mode CPU, m{M} k{K} n{N}, "
               f"jax {jax.__version__}")
    table.save(out_path)
    print(f"wrote {len(tiles)} cell tiles to {out_path}")
    return table


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dispatch.json",
                    help="per-operating-point baseline JSON (perf trajectory)")
    ap.add_argument("--no-json", action="store_true",
                    help="stdout table only (benchmarks.run aggregate mode)")
    ap.add_argument("--retune", action="store_true",
                    help="sweep Tiles per cell and rewrite the shipped "
                         "TuneTable instead of benching")
    ap.add_argument("--tune-out", default=dispatch.DEFAULT_TUNE_PATH)
    args = ap.parse_args(argv)

    if args.retune:
        print("# kernel_bench --retune (per-cell Tile sweep)")
        retune(args.tune_out)
        return

    print("# kernel_bench (interpret-mode validation + VMEM tile model)")
    print("op,name,us_per_call,tile,vmem")
    rows = run()
    for r in rows:
        op = r["op"]
        optag = (f"w{op['wprec']}/a{op['aprec']}/{op['impl']}@{op['backend']}"
                 if op else "-")
        tile = r["tile"]
        tstr = f"{tile['bm']}x{tile['bn']}x{tile['bkq']}" if tile else "-"
        vm = (f"{r['vmem_tile_bytes']/2**10:.0f}KiB"
              if r["vmem_tile_bytes"] else "-")
        print(f"{optag},{r['name']},{r['us_per_call']:.0f},{tstr},{vm}")
    if not args.no_json:
        with open(args.out, "w") as f:
            json.dump({"bench": "dispatch_qgemm", "m": M, "k": K, "n": N,
                       "interpret": dispatch.INTERPRET,
                       "tune_source": dispatch.default_tune().source,
                       "rows": rows}, f, indent=2)
            f.write("\n")
        print(f"(baseline written to {args.out})")


if __name__ == "__main__":
    main()
