"""Pallas kernel micro-bench: interpret-mode wall time (correctness-scale) +
the analytic TPU tile model for each kernel's BlockSpec choice.

Driven by the `repro.kernels.dispatch` registry: every registered operating
point with a Pallas MacBody is benched through the single `qgemm` entry
point (so the bench exercises exactly the code the serve stack runs —
activation prep, padding, fused bias epilogue and all). Registering a new
precision/kernel variant adds a bench row automatically.

Wall time in interpret mode is NOT TPU performance — it validates the
kernels execute and lets us compare formulations structurally. The derived
column is the VMEM working set of the default block shapes (must be
<< 128 MiB), from `harness.vmem_tile_bytes`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlinear
from repro.core.precision import LayerQuant
from repro.core.quantize import QuantSpec
from repro.kernels import dispatch, harness


def run():
    m, k, n = 128, 1024, 128
    rows = []

    for key in sorted(dispatch.cells()):
        cell = dispatch.cells()[key]
        if cell.body is None:        # weight-only/dense: no packed kernel
            continue
        spec = qlinear.QLinearSpec(
            k, n, LayerQuant(QuantSpec(cell.wprec), QuantSpec(cell.aprec)))
        p = qlinear.pack_params(
            qlinear.init(jax.random.PRNGKey(0), spec), spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k)) * 0.2
        impl = "popcount" if cell.impl == "*" else cell.impl
        y = dispatch.qgemm(p, x, spec, impl=impl, backend="pallas")
        jax.block_until_ready(y)                      # compile outside timing
        t0 = time.perf_counter()
        jax.block_until_ready(
            dispatch.qgemm(p, x, spec, impl=impl, backend="pallas"))
        dt = time.perf_counter() - t0
        rows.append((cell.body.name, dt * 1e6,
                     f"vmem={harness.vmem_tile_bytes(cell.body)/2**10:.0f}KiB"))

    from repro.kernels.flash_attn import flash_attention
    ks3 = jax.random.split(jax.random.PRNGKey(3), 3)
    qf = jax.random.normal(ks3[0], (4, 256, 64), jnp.float32)
    kf = jax.random.normal(ks3[1], (2, 256, 64), jnp.float32)
    vf = jax.random.normal(ks3[2], (2, 256, 64), jnp.float32)
    fa = lambda: flash_attention(qf, kf, vf, causal=True, bq=128, bk=128)
    jax.block_until_ready(fa())                       # compile outside timing
    t0 = time.perf_counter()
    jax.block_until_ready(fa())
    rows.append(("flash_attn", (time.perf_counter() - t0) * 1e6,
                 f"vmem={(128*64*4*2 + 128*64*4 + 2*128*4)/2**10:.0f}KiB"))
    return rows


def main():
    print("# kernel_bench (interpret-mode validation + VMEM tile model)")
    print("name,us_per_call,derived")
    for name, us, d in run():
        print(f"{name},{us:.0f},{d}")


if __name__ == "__main__":
    main()
