"""Pallas kernel micro-bench: interpret-mode wall time (correctness-scale) +
the analytic TPU tile model for each kernel's BlockSpec choice.

Wall time in interpret mode is NOT TPU performance — it validates the kernels
execute and lets us compare formulations structurally. The derived column is
the VMEM working set of the chosen block shapes (must be << 128 MiB).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack
from repro.kernels import bgemm, i8gemm, tgemm


def _vmem_bytes(bm, bn, bkw_words, acc_dtype_bytes=4, nacc=1):
    # x tile + w tile + acc scratch + out tile
    return (bm * bkw_words * 4 + bn * bkw_words * 4
            + nacc * bm * bn * acc_dtype_bytes + bm * bn * 2)


def run():
    rng = np.random.default_rng(2)
    m, k, n = 128, 1024, 128
    rows = []

    xp = pack.pack_binary(jnp.asarray(np.sign(rng.standard_normal((m, k))) + 0.0))
    wp = pack.pack_binary(jnp.asarray(np.sign(rng.standard_normal((n, k))) + 0.0))
    ws = jnp.ones((n,), jnp.float32)
    as_ = jnp.ones((m,), jnp.float32)
    for impl in ("popcount", "mxu"):
        t0 = time.perf_counter()
        y = bgemm.bgemm(xp, wp, ws, as_, k=k, impl=impl)
        jax.block_until_ready(y)
        dt = time.perf_counter() - t0
        rows.append(("bgemm_" + impl, dt * 1e6,
                     f"vmem={_vmem_bytes(128, 128, 16)/2**10:.0f}KiB"))

    xt = jnp.asarray(rng.integers(-1, 2, (m, k)).astype(np.float32))
    wt = jnp.asarray(rng.integers(-1, 2, (n, k)).astype(np.float32))
    xm, xs = pack.pack_ternary(xt)
    wm, wsgn = pack.pack_ternary(wt)
    t0 = time.perf_counter()
    y = tgemm.tgemm(xm, xs, wm, wsgn, ws, as_, k=k)
    jax.block_until_ready(y)
    rows.append(("tgemm", (time.perf_counter() - t0) * 1e6,
                 f"vmem={_vmem_bytes(128, 128, 16, nacc=2)/2**10:.0f}KiB"))

    from repro.kernels.flash_attn import flash_attention
    ks3 = jax.random.split(jax.random.PRNGKey(3), 3)
    qf = jax.random.normal(ks3[0], (4, 256, 64), jnp.float32)
    kf = jax.random.normal(ks3[1], (2, 256, 64), jnp.float32)
    vf = jax.random.normal(ks3[2], (2, 256, 64), jnp.float32)
    t0 = time.perf_counter()
    jax.block_until_ready(flash_attention(qf, kf, vf, causal=True, bq=128, bk=128))
    rows.append(("flash_attn", (time.perf_counter() - t0) * 1e6,
                 f"vmem={(128*64*4*2 + 128*64*4 + 2*128*4)/2**10:.0f}KiB"))

    xq = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    t0 = time.perf_counter()
    y = i8gemm.i8gemm(xq, wq, ws, as_)
    jax.block_until_ready(y)
    rows.append(("i8gemm", (time.perf_counter() - t0) * 1e6,
                 f"vmem={(128*512 + 512*128 + 128*128*4 + 128*128*2)/2**10:.0f}KiB"))
    return rows


def main():
    print("# kernel_bench (interpret-mode validation + VMEM tile model)")
    print("name,us_per_call,derived")
    for name, us, d in run():
        print(f"{name},{us:.0f},{d}")


if __name__ == "__main__":
    main()
