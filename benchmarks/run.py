"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

  energy_proxy  Fig. 5 (per-precision energy breakdown -> traffic/roofline)
  throughput    Table I KPIs (614/307/77 GOPS b/t/i8)
  kernel_bench  Pallas kernels: interpret validation + VMEM tile model
  flexibility   Table I flexibility rows (arch x policy support matrix)
  qat_quality   §II-A mixed-precision motivation (QAT loss per policy)
  serve_bench   KV layouts + scheduler: paged vs contiguous, prefix-share
                admitted throughput, preempt-vs-reserve (docs/SERVING.md)
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow QAT sweep")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import energy_proxy, flexibility, kernel_bench, throughput

    benches = [("energy_proxy", energy_proxy.main),
               ("throughput", throughput.main),
               ("kernel_bench", lambda: kernel_bench.main(["--no-json"]))]
    if not args.quick:
        from benchmarks import qat_quality, serve_bench
        benches += [("flexibility", flexibility.main),
                    ("qat_quality", qat_quality.main),
                    ("serve_bench", lambda: serve_bench.main([]))]
    for name, fn in benches:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"\n==== {name} ====")
        fn()
        print(f"({name}: {time.time()-t0:.0f}s)")


if __name__ == '__main__':
    main()
