"""Table I flexibility-rows analogue.

The paper's differentiator is not peak efficiency but that BrainTTA *runs
anything*: any layer geometry (C multiple of 32/16/4, M of 32, any R/S),
partial results, residual layers, C-programmability. Our analogue: every
assigned architecture × every precision policy must build and run a forward
step — a 10x5 support matrix — plus the utilization-divisibility conditions
(our v_C analogue is the 32-bit packing word + the 16-way TP axis).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.core.quantize import PACK_FACTOR
from repro.models import registry, transformer
from repro.models.common import TRAIN

POLICIES = ("none", "int8", "w-ternary", "mixed", "binary",
            "wt-a8", "w4a8", "het")


def run(quick: bool = True) -> dict:
    support: dict[str, dict[str, str]] = {}
    for arch in ARCHS:
        support[arch] = {}
        for pol in POLICIES:
            cfg = dataclasses.replace(get_config(arch).reduced(), policy=pol)
            try:
                t0 = time.time()
                sp = transformer.build_specs(cfg)
                params = transformer.init(jax.random.PRNGKey(0), cfg)
                batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 1, 8)
                loss, _ = transformer.loss_fn(params, batch, sp, TRAIN)
                ok = bool(jnp.isfinite(loss))
                support[arch][pol] = f"ok({time.time()-t0:.0f}s)" if ok else "nan"
            except Exception as e:
                support[arch][pol] = f"FAIL:{type(e).__name__}"
    return support


def main():
    print("# flexibility (paper Table I rows: full-utilization conditions + support)")
    print("## utilization conditions (v_C analogue)")
    print("precision,packing(ops/word),K_multiple_of,TP_axis_multiple")
    # K granularity = the storage-word quantum (pack.K_QUANTUM): 32 for the
    # bit-plane formats (a trit spans two 32-bit planes), 8 for s4 nibbles,
    # 4 for int8's native byte layout
    k_mult = {"binary": 32, "ternary": 32, "int4": 8, "int8": 4}
    for p, f in PACK_FACTOR.items():
        print(f"{p},{f},{k_mult[p]},16")
    sup = run()
    print("## arch x policy support matrix")
    print("arch," + ",".join(POLICIES))
    for arch, row in sup.items():
        print(arch + "," + ",".join(row[p] for p in POLICIES))
    return sup


if __name__ == "__main__":
    main()
