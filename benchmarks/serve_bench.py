"""Serving-layer bench: KV layouts and scheduler policies under arrival
traffic (docs/SERVING.md).

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch llama3.2-3b]
                                                    [--json BENCH_serve.json]
                                                    [--scenario poisson]

Scenarios:
  mixed         paged vs contiguous layout on mixed-length traffic — the
                paged win is *capacity* (the slab reserves slots*cache_len
                tokens up front, the pool holds only live coverage)
  shared-prefix identical 16-token prompt prefixes over a constrained pool,
                --prefix-share off vs on — the sharing win is *admitted
                throughput* (tokens per fused decode tick): aliased pages
                let every request co-run where the baseline serializes waves
  oversubscribed a pool smaller than the aggregate decode lifetime,
                conservative reservation vs --preempt — preemption converts
                reserved-but-idle headroom into live decode slots, at the
                cost of swap traffic (counted)
  spec          self-speculative decoding on the w4a8 policy: the SAME
                plane-stacked weights serve as their own draft model
                (--spec-draft planes:1), sequential decode vs a K-token
                propose/verify tick. Run on draft-friendly weights — every
                code floor-snapped to its top plane so the truncated-plane
                draft composes the full-precision value exactly — the
                accept rate approaches 100% and the win is *tokens per
                verify tick* (spec_tokens_per_tick_speedup headline;
                acceptance floor 1.3x). On un-snapped random weights a
                1-plane draft accepts ~nothing (measured 0%): accept rate
                is a property of how much of the weight's energy the top
                planes carry, which real quantized checkpoints — unlike
                random init — concentrate there
  poisson       OPEN-LOOP arrival process: Poisson arrivals of a long/short
                prompt mix (default 25% long at 0.75*cache_len), whole-prompt
                prefill vs --chunk-tokens. Reports wall-clock p50/p99 TTFT
                (scheduled arrival -> first token) and inter-token latency
                per request. The chunked win is the *latency tail*: a long
                prompt's prefill no longer freezes every in-flight decode
                slot for a whole jitted prefill call, so the p99 TTFT of the
                short requests queued behind it collapses
                (poisson_p99_ttft_speedup headline; acceptance floor 2x).
                Arrival times are calibrated once against the baseline's
                measured tick time and REUSED for the chunked run, so both
                configs face the identical offered load; jit compile time is
                excluded by a warmup workload that touches every signature
                before the clock starts

  moe           MoE routing telemetry on deepseek-moe: identical traffic at
                router-capacity headroom vs a drop-forcing capacity_factor,
                reporting per-expert utilization and the drop rate straight
                from Server.stats (docs/MOE.md) — the columns the EP serving
                deployment monitors
  multi-tenant  two tenants (pure-attn + windowed arch, different precision
                policies) co-scheduled on ONE shared page pool with prefix
                sharing, preemption and the tiered (device→host→disk)
                prefix cache, then a cold-restart pass over the same slab
                directory — the reuse win is *prefix pages promoted from
                the tier instead of re-prefilled* (per-tenant p50/p99
                TTFT/ITL from the SLO counters ride along)

A further micro-scenario, `decode-attn`, drops below the scheduler and times
the decode attention READ path itself at a fixed provisioned page-table
width while the active length sweeps 128→4096: the jitted server's gather
path always materializes (and dequantizes) the full table width per step,
the fused page-walk kernel (kernels/paged_attn) stops at the slot's last
active page. Interpret-mode wall time is NOT TPU performance; the modeled
per-step HBM KV traffic column is the layout-level metric, wall time is
reported alongside for the CPU lane.

Reports tok/s and tok/tick per row, jit signature counts (the bucketing +
fixed-decode + CoW discipline), page/pool utilization, and scheduler stats;
`--json` writes the whole table plus the headline ratios for the CI bench
lane (BENCH_serve.json artifact).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx


def _mixed_traffic(cfg, n, rng):
    return [Request(i, rng.integers(0, cfg.vocab,
                                    size=(int(rng.integers(2, 25)),)).astype(np.int32),
                    int(rng.integers(4, 13)))
            for i in range(n)]


def _shared_traffic(cfg, n, rng, prefix_len=16, tail=2, max_new=6):
    common = rng.integers(0, cfg.vocab, size=(prefix_len,)).astype(np.int32)
    return [Request(i, np.concatenate(
        [common, rng.integers(0, cfg.vocab, size=(tail,)).astype(np.int32)]),
        max_new) for i in range(n)]


def _run_one(cfg, sparams, reqs, *, label, scenario, **kw):
    srv = Server(cfg, sparams, ctx=ModelCtx(mode="serve"), **kw)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    ticks = srv.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in srv.completed)
    row = dict(
        scenario=scenario, config=label,
        tok_s=toks / dt, tok_per_tick=toks / max(ticks, 1), ticks=ticks,
        jit_prefill=srv.compile_counts["prefill"],
        jit_decode=srv.compile_counts["decode"],
        jit_cow=srv.compile_counts["cow"],
    )
    if srv.paged:
        # peak_pages is measured at the pool (shared pages count once) —
        # with sharing on it can be far below the per-slot coverage sum
        row.update(kv_reserved_tokens=srv.pt.usable_pages * srv.page_size,
                   kv_peak_live_pages=srv.stats["peak_pages"],
                   **{k: v for k, v in srv.stats.items() if k != "peak_pages"})
    else:
        row.update(kv_reserved_tokens=srv.slots * srv.cache_len,
                   kv_peak_live_pages="-",
                   **{k: v for k, v in srv.stats.items() if k != "peak_pages"})
    return row


def run(arch="llama3.2-3b", requests=12, slots=4, cache_len=128, page_size=16):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, policy="ternary")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    rows = []

    # -- mixed-length traffic: paged vs contiguous (identical traffic) -------
    for paged in (True, False):
        rows.append(_run_one(
            cfg, sparams, _mixed_traffic(cfg, requests, np.random.default_rng(0)),
            label="paged" if paged else "contiguous", scenario="mixed",
            slots=slots, cache_len=cache_len, paged=paged, page_size=page_size))

    # -- shared-prefix workload over a constrained pool: sharing off vs on ---
    # geometry mirrors tests/test_serving_sched.py::test_prefix_share_
    # throughput...: 4 requests x (16 shared + 2 private) tokens, 6 new each;
    # 12 usable pages of 4 fit all four concurrently ONLY when the common
    # prefix aliases
    sh_kw = dict(slots=4, cache_len=32, paged=True, page_size=4, num_pages=13)
    for share in (False, True):
        rows.append(_run_one(
            cfg, sparams, _shared_traffic(cfg, 4, np.random.default_rng(1)),
            label="share-on" if share else "share-off",
            scenario="shared-prefix", prefix_share=share, **sh_kw))

    # -- oversubscribed pool: conservative reservation vs preempt+swap -------
    ov_rng = np.random.default_rng(2)
    ov_prompts = [ov_rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
                  for _ in range(3)]
    ov_kw = dict(slots=3, cache_len=32, paged=True, page_size=4, num_pages=9)
    for preempt in (False, True):
        reqs = [Request(i, p, 12) for i, p in enumerate(ov_prompts)]
        rows.append(_run_one(
            cfg, sparams, reqs,
            label="preempt" if preempt else "reserve",
            scenario="oversubscribed", preempt=preempt, **ov_kw))
    return rows


def _snap_low_planes(sparams, keep=1):
    """Draft-friendly weights: floor-snap every plane-stacked weight's codes
    to their top `keep` plane(s), regenerating BOTH the plane stack and the
    direct twin from the snapped codes (scales untouched), so the serving
    comparison stays apples-to-apples — sequential and speculative runs see
    the identical model, and the truncated-plane draft composes exactly the
    values the full cell reads."""
    from repro.core import pack

    def walk(t):
        if not isinstance(t, dict):
            return t
        t = {k: walk(v) for k, v in t.items()}
        planes = t.get("w_planes")
        if planes is None:
            return t
        bits = planes.shape[-3]
        k = planes.shape[-1] * pack.WORD
        codes = np.asarray(pack.unpack_planes_i8(planes, k, bits))
        sh = bits - min(keep, bits)
        codes = ((codes >> sh) << sh).astype(np.int8)   # arithmetic: floor
        t["w_planes"] = pack.pack_planes(codes, bits)
        if "w_q4" in t:                    # int4 twin: (out, in) nibbles
            t["w_q4"] = pack.pack_int4(codes)
        elif "w_q" in t:                   # int8 twin: (in, out) codes
            t["w_q"] = jax.numpy.asarray(np.swapaxes(codes, -1, -2))
        return t

    return walk(sparams)


def spec_rows(arch="llama3.2-3b", *, requests=6, slots=2, cache_len=64,
              page_size=8, max_new=16, spec_k=4):
    """The `spec` scenario: identical requests and identical (snapped)
    weights, sequential decode vs self-speculative propose/verify."""
    cfg = dataclasses.replace(get_config(arch).reduced(), policy="w4a8")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = _snap_low_planes(
        transformer.pack_for_serve(params, cfg, plane_twins=True))
    kw = dict(slots=slots, cache_len=cache_len, paged=True,
              page_size=page_size)
    rows = []
    for label, skw in (("sequential", {}),
                       ("speculative",
                        dict(spec_draft="planes:1", spec_k=spec_k))):
        rng = np.random.default_rng(4)      # identical traffic both arms
        reqs = [Request(i, rng.integers(0, cfg.vocab, size=(int(rng.integers(
            4, 17)),)).astype(np.int32), max_new) for i in range(requests)]
        rows.append(_run_one(cfg, sparams, reqs, label=label, scenario="spec",
                             **kw, **skw))
    return rows


def moe_rows(arch="deepseek-moe-16b", *, requests=6, slots=2, cache_len=64,
             page_size=8):
    """The `moe` scenario: identical mixed-length traffic through an MoE
    arch at two router capacities — the reduced default (capacity_factor=8,
    headroom for every top-k assignment) vs a deliberately tight 0.5 that
    forces slot-overflow drops. The routing telemetry the server accumulates
    (Server.stats: moe_routed / moe_dropped / moe_expert_tokens, see
    docs/MOE.md §Stats) surfaces as per-row columns: `moe_drop_rate` is
    dropped/routed, `moe_expert_util` each expert's share of the kept
    assignments. Single-process rows — EP changes the *placement* of this
    exact computation, not the counters (tests/test_moe_serving.py holds the
    stats shard-count-invariant), so the utilization/drop columns here stand
    for the sharded deployment too."""
    rows = []
    for label, cap in (("capacity-headroom", None), ("capacity-tight", 0.5)):
        cfg = get_config(arch).reduced()
        if cap is not None:
            cfg = dataclasses.replace(cfg, capacity_factor=cap)
        params = transformer.init(jax.random.PRNGKey(0), cfg)
        sparams = transformer.pack_for_serve(params, cfg)
        row = _run_one(
            cfg, sparams, _mixed_traffic(cfg, requests, np.random.default_rng(5)),
            label=label, scenario="moe", slots=slots, cache_len=cache_len,
            paged=True, page_size=page_size)
        et = row.pop("moe_expert_tokens")
        kept = sum(et)
        row["capacity_factor"] = cfg.capacity_factor
        row["moe_drop_rate"] = row["moe_dropped"] / max(row["moe_routed"], 1)
        row["moe_expert_util"] = "|".join(
            f"{v / max(kept, 1):.3f}" for v in et)
        rows.append(row)
    return rows


def decode_attn_rows(active_lens=(128, 512, 1024, 2048, 4096), *, slots=4,
                     page_size=64, table_pages=128, hk=2, hq=4, dh=32,
                     reps=20):
    """`decode-attn` micro-scenario: per-step attention read-path time at a
    FIXED provisioned table width (table_pages * page_size = 8192 tokens),
    active length swept. Three variants per length:

      gather-full     what the jitted server's gather path pays every step
                      (pos is a tracer -> the full fixed-signature width is
                      gathered + dequantized)
      gather-bounded  the eager length-bound (attn_decode slices the table
                      to max(pos)//P + 1 columns) — oracle/bench callers
      fused           kernels.paged_attn.paged_flash_decode — the page walk
                      early-stops at each slot's last active page

    int8 pool so the gather's full-width dequantize cost is visible.
    `hbm_kv_bytes_per_step` models the pool operand traffic each variant
    actually touches (the TPU-relevant metric; wall time here is
    interpret-mode CPU)."""
    import jax.numpy as jnp

    from repro.kernels import paged_attn as pa
    from repro.kernels.dispatch import INTERPRET
    from repro.models.attention import KV_SCALE

    num_pages = 1 + slots * table_pages
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (slots, hq, dh), jnp.float32)
    kp = jax.random.randint(kk, (num_pages, page_size, hk, dh), -127, 128,
                            jnp.int8)
    vp = jax.random.randint(kv, (num_pages, page_size, hk, dh), -127, 128,
                            jnp.int8)
    pages = np.stack([1 + r * table_pages + np.arange(table_pages)
                      for r in range(slots)]).astype(np.int32)
    pages = jnp.asarray(pages)

    @jax.jit
    def gather(pages_, pos_):
        s = pages_.shape[1] * page_size
        kf = kp[pages_].reshape(slots, s, hk, dh).astype(jnp.float32) * KV_SCALE
        vf = vp[pages_].reshape(slots, s, hk, dh).astype(jnp.float32) * KV_SCALE
        valid = jnp.arange(s)[None, :] <= pos_[:, None]
        qg = q.reshape(slots, hk, hq // hk, dh)
        sc = jnp.einsum("bhgd,bshd->bhgs", qg, kf) / dh ** 0.5
        sc = jnp.where(valid[:, None, None, :], sc, -1e30)
        a = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bhgs,bshd->bhgd", a, vf)

    def time_us(fn):
        jax.block_until_ready(fn())                      # compile outside
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / reps * 1e6

    bkp = pa.resolve_pages_per_block()
    kv_bytes = 1                                          # int8 pool
    rows = []
    for al in active_lens:
        pos = jnp.full((slots,), al - 1, jnp.int32)
        act_pages = (al - 1) // page_size + 1
        full_b = slots * 2 * table_pages * page_size * hk * dh * kv_bytes
        act_b = slots * 2 * act_pages * page_size * hk * dh * kv_bytes
        for name, fn, bb in (
            ("gather-full", lambda: gather(pages, pos), full_b),
            ("gather-bounded",
             lambda: gather(pages[:, :act_pages], pos), act_b),
            ("fused", lambda: pa.paged_flash_decode(
                q, kp, vp, pages, pos, pages_per_block=bkp,
                kv_scale=KV_SCALE, interpret=INTERPRET), act_b),
        ):
            rows.append(dict(scenario="decode-attn", config=name,
                             active_len=al, us_per_step=time_us(fn),
                             hbm_kv_bytes_per_step=bb,
                             pages_per_block=bkp if name == "fused" else "-"))
    return rows


def multi_tenant_rows(*, requests=3, max_new=6, cache_len=32, page_size=4,
                      tier_dir=None):
    """The `multi-tenant` scenario: two tenants (pure-attn llama + windowed
    gemma, different precision policies) co-scheduled on ONE shared page
    pool with prefix sharing, cross-tenant preemption, and the tiered
    prefix cache — then a COLD-RESTART pass: a fresh MultiServer over the
    same disk-slab directory serving identical traffic, measuring how many
    prefixes it re-admits from the tier instead of re-prefilling
    (`tier_hits`, `prefill_skips`). Per-tenant p50/p99 TTFT/ITL come from
    the scheduler's SLO counters (ticks are the interpret-mode-stable
    metric; wall seconds ride along). Pool occupancy is PageTable.stats()'s
    live/usable fraction — page 0 scratch is not demand."""
    import tempfile
    import zlib

    from repro.launch.cache_tiers import PageStore
    from repro.launch.multi_serve import MultiServer, TenantSpec

    tier_dir = tier_dir or tempfile.mkdtemp(prefix="serve-bench-tier-")
    tenants = [
        TenantSpec(model_id="llama#0", arch="llama3.2-3b", policy="ternary",
                   slots=2, cache_len=cache_len, weight=2, priority=1,
                   reduced=True),
        TenantSpec(model_id="gemma#1", arch="gemma3-4b", policy="w-ternary",
                   slots=2, cache_len=cache_len, weight=1, priority=0,
                   reduced=True),
    ]

    def traffic(t, vocab):
        # page-aligned common prefix, stable per tenant AND across phases,
        # so the share index aliases within a phase and the restart pass
        # probes the exact disk-tier keys the cold pass flushed
        prng = np.random.default_rng(zlib.crc32(t.model_id.encode()))
        head = prng.integers(0, vocab, size=(page_size,))
        tails = np.random.default_rng(1)
        return [np.concatenate(
            [head, tails.integers(0, vocab, size=(3 + 2 * i,))]
        ).astype(np.int32) for i in range(requests)]

    rows = []
    for phase in ("cold", "restart"):
        ms = MultiServer(tenants, page_size=page_size, prefix_share=True,
                         preempt=True,
                         tier=PageStore(host_capacity=16, disk_dir=tier_dir))
        for t in tenants:
            for p in traffic(t, ms.servers[t.model_id].cfg.vocab):
                ms.submit(t.model_id, p, max_new)
        t0 = time.perf_counter()
        ticks = ms.run()
        dt = time.perf_counter() - t0
        ms.flush_tier()
        stt = ms.stats()
        for t in tenants:
            r = stt[t.model_id]
            toks = sum(len(q.out)
                       for q in ms.servers[t.model_id].completed)
            rows.append(dict(
                scenario="multi-tenant", config=f"{phase}:{t.model_id}",
                completed=r["completed"], tok_s=toks / dt,
                tok_per_tick=toks / max(ticks, 1),
                ttft_p50_ticks=r["ttft_ticks_p50"],
                ttft_p99_ticks=r["ttft_ticks_p99"],
                itl_p50_ticks=r["itl_ticks_p50"],
                itl_p99_ticks=r["itl_ticks_p99"],
                ttft_p50_s=r["ttft_s_p50"], ttft_p99_s=r["ttft_s_p99"],
                itl_p50_s=r["itl_s_p50"], itl_p99_s=r["itl_s_p99"],
                shared_pages=r["shared_pages"],
                preemptions=r["preemptions"],
                tier_hits=(r["tier_hits_device"] + r["tier_hits_host"]
                           + r["tier_hits_disk"]),
                tier_hits_promoted=(r["tier_hits_host"]
                                    + r["tier_hits_disk"]),
                prefill_skips=r["prefill_skips"],
                jit_signatures=r["jit_signatures"],
                pool_occupancy_exit=stt["pool"]["occupancy"],
            ))
    return rows


def _poisson_traffic(cfg, n, rng, cache_len, max_new, long_frac=0.25):
    """Open-loop arrival schedule: (arrival_gap_units, Request) with unit-mean
    exponential inter-arrival gaps (scaled to seconds by the caller) and a
    long/short prompt mix — long prompts are 0.75*cache_len, the tail that
    whole-prompt prefill turns into a decode freeze."""
    t, out = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0))
        plen = ((3 * cache_len) // 4 if rng.random() < long_frac
                else int(rng.integers(4, 17)))
        out.append((t, Request(
            i, rng.integers(0, cfg.vocab, size=(plen,)).astype(np.int32),
            max_new)))
    return out


def _pct(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q)) if vals else 0.0


def _run_arrivals(cfg, sparams, traffic, *, label, chunk_tokens, gap_s=None,
                  **kw):
    """Serve an open-loop arrival schedule; returns (row, gap_s).

    `gap_s` scales the unit-mean arrival gaps to seconds. None = calibrate
    from this run's warmup tick time (the baseline does this; the chunked run
    reuses the same value so both face the identical offered load). TTFT
    counts from the SCHEDULED arrival, not the actual submit — when the loop
    is stuck inside a long prefill, that queueing delay is the metric."""
    srv = Server(cfg, sparams, ctx=ModelCtx(mode="serve"),
                 chunk_tokens=chunk_tokens, **kw)
    # warmup on the same instance: touch every signature the measured run
    # will hit (short + long prefill buckets or the chunk step, decode), so
    # no jit compile lands inside a timed tick
    wrng = np.random.default_rng(99)
    # one warmup request per prefill bucket the traffic actually hits (the
    # chunked arm has no buckets — its requests warm the chunk + decode
    # signatures instead); a missed bucket would drop a multi-second jit
    # compile into the middle of the timed loop and corrupt the TTFT tail
    if srv.chunk_tokens:
        warm_lens = (4, (3 * srv.cache_len) // 4)
    else:
        by_bucket: dict = {}
        for _, req in traffic:
            b = srv._bucket(len(req.prompt))
            by_bucket[b] = max(by_bucket.get(b, 0), len(req.prompt))
        warm_lens = sorted(by_bucket.values())
    for j, plen in enumerate(warm_lens):
        srv.submit(Request(10_000 + j,
                           wrng.integers(0, cfg.vocab, size=(plen,))
                           .astype(np.int32), 2))
    srv.run()
    if gap_s is None:
        # calibrate on a SECOND, hot warmup pass: the first run's wall time
        # is dominated by jit compiles, which would inflate the arrival gaps
        # by orders of magnitude and turn the open loop into an idle crawl.
        # mean inter-arrival = 2 hot ticks: with slots*max_new decode ticks
        # of work per request this offers near-saturation load, where the
        # latency tail actually separates the two prefill policies
        for j in range(2):
            srv.submit(Request(20_000 + j,
                               wrng.integers(0, cfg.vocab, size=(6,))
                               .astype(np.int32), 4))
        wt0 = time.perf_counter()
        wticks = srv.run()
        gap_s = 2.0 * (time.perf_counter() - wt0) / max(wticks, 1)
    srv.completed.clear()

    arr = [(g * gap_s, req) for g, req in traffic]
    n = len(arr)
    submit_t, first_t, done_t = {}, {}, {}
    reqs = {req.rid: req for _, req in arr}
    i = 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while i < n and arr[i][0] <= now:
            ts, req = arr[i]
            srv.submit(req)
            submit_t[req.rid] = ts
            i += 1
        busy = (srv.queue or srv.preempted
                or any(r is not None for r in srv.slot_req))
        if not busy:
            if i >= n:
                break
            time.sleep(max(0.0, arr[i][0] - (time.perf_counter() - t0)))
            continue
        srv.step()
        now = time.perf_counter() - t0
        for rid, req in reqs.items():
            if rid not in submit_t:
                continue
            if req.out and rid not in first_t:
                first_t[rid] = now
            if req.done and rid not in done_t:
                done_t[rid] = now
    ttft = [first_t[r] - submit_t[r] for r in first_t]
    itl = [(done_t[r] - first_t[r]) / (len(reqs[r].out) - 1)
           for r in done_t if len(reqs[r].out) > 1]
    toks = sum(len(r.out) for r in reqs.values())
    span = max(done_t.values()) if done_t else 1.0
    row = dict(
        scenario="poisson", config=label,
        ttft_p50_s=_pct(ttft, 50), ttft_p99_s=_pct(ttft, 99),
        itl_p50_s=_pct(itl, 50), itl_p99_s=_pct(itl, 99),
        tok_s=toks / span, requests=n,
        mean_interarrival_s=gap_s,
        jit_total=sum(srv.compile_counts.values()),
        chunk_ticks=srv.stats["chunk_ticks"],
        plan_hits=srv.stats["plan_hits"], fences=srv.stats["fences"],
    )
    return row, gap_s, srv


def poisson_rows(cfg, sparams, *, requests=24, slots=4, cache_len=128,
                 page_size=16, max_new=8, chunk_tokens=16):
    """The arrival-process scenario: identical Poisson schedule, whole-prompt
    prefill vs chunked prefill fused into the decode tick."""
    kw = dict(slots=slots, cache_len=cache_len, paged=True,
              page_size=page_size)
    rows, gap, servers = [], None, []
    for label, ct in (("whole-prompt", 0), ("chunked", chunk_tokens)):
        traffic = _poisson_traffic(cfg, requests, np.random.default_rng(3),
                                   cache_len, max_new)
        row, gap, srv = _run_arrivals(cfg, sparams, traffic, label=label,
                                      chunk_tokens=ct, gap_s=gap, **kw)
        rows.append(row)
        servers.append(srv)
    return rows, servers


def _ratio(rows, scenario, a, b, key="tok_per_tick"):
    sel = {r["config"]: r[key] for r in rows if r["scenario"] == scenario}
    return sel[a] / sel[b]


def _print_rows(rows, header):
    print(header)
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(f"{r[k]:.4f}" if isinstance(r[k], float) else str(r[k])
                       for k in keys))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--scenario", default="all",
                    choices=("all", "scheduler", "decode-attn", "poisson",
                             "spec", "multi-tenant", "moe"),
                    help="'scheduler' = the mixed/shared-prefix/"
                         "oversubscribed trio; 'poisson' = the open-loop "
                         "arrival-process scenario only (the CI serving-lane "
                         "smoke); 'spec' = self-speculative decoding on "
                         "draft-friendly snapped w4a8 weights; "
                         "'multi-tenant' = two archs x two policies on one "
                         "shared pool + tiered cache, with a cold-restart "
                         "prefix-reuse pass; 'moe' = MoE routing telemetry "
                         "(expert utilization + drop rate) at headroom vs "
                         "drop-forcing router capacity")
    ap.add_argument("--tier-dir", default=None,
                    help="disk-slab directory for the multi-tenant "
                         "scenario's tiered cache (default: a temp dir)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="tokens proposed per tick in the spec scenario")
    ap.add_argument("--poisson-requests", type=int, default=24)
    ap.add_argument("--chunk-tokens", type=int, default=16,
                    help="chunk size for the poisson scenario's chunked arm")
    ap.add_argument("--jit-budget", type=int, default=None,
                    help="fail (exit 1) if any poisson-scenario server "
                         "traced more total jit signatures than this — the "
                         "CI recompile-regression gate for the arrival "
                         "smoke")
    ap.add_argument("--json", default=None, metavar="OUT_JSON",
                    help="write rows + headline ratios (BENCH_serve.json "
                         "artifact for the CI bench lane)")
    args = ap.parse_args(argv)
    out = {}
    all_rows = []

    if args.scenario in ("all", "scheduler"):
        rows = run(args.arch, args.requests, args.slots, args.cache_len,
                   args.page_size)
        _print_rows(rows, "# serve bench (identical traffic within each "
                          "scenario)")
        share_x = _ratio(rows, "shared-prefix", "share-on", "share-off")
        preempt_x = _ratio(rows, "oversubscribed", "preempt", "reserve")
        print(f"# shared-prefix admitted-throughput: {share_x:.2f}x with "
              f"--prefix-share (acceptance floor 1.5x)")
        print(f"# oversubscribed admitted-throughput: {preempt_x:.2f}x with "
              f"--preempt")
        out.update(rows=rows, shared_prefix_speedup_tok_per_tick=share_x,
                   preempt_speedup_tok_per_tick=preempt_x)
        all_rows += rows

    if args.scenario in ("all", "poisson"):
        cfg = dataclasses.replace(get_config(args.arch).reduced(),
                                  policy="ternary")
        params = transformer.init(jax.random.PRNGKey(0), cfg)
        sparams = transformer.pack_for_serve(params, cfg)
        prows, servers = poisson_rows(
            cfg, sparams, requests=args.poisson_requests, slots=args.slots,
            cache_len=args.cache_len, page_size=args.page_size,
            chunk_tokens=args.chunk_tokens)
        _print_rows(prows, "# poisson arrival scenario (open loop, identical "
                           "schedule; wall-clock seconds)")
        sel = {r["config"]: r for r in prows}
        ttft_x = (sel["whole-prompt"]["ttft_p99_s"]
                  / max(sel["chunked"]["ttft_p99_s"], 1e-9))
        print(f"# poisson p99 TTFT: {ttft_x:.2f}x better with chunked "
              f"prefill (acceptance floor 2x)")
        out.update(poisson_rows=prows, poisson_p99_ttft_speedup=ttft_x)
        all_rows += prows
        if args.jit_budget is not None:
            for r in prows:
                if r["jit_total"] > args.jit_budget:
                    raise SystemExit(
                        f"jit budget exceeded in poisson scenario "
                        f"({r['config']}): {r['jit_total']} signatures > "
                        f"committed budget {args.jit_budget}")

    if args.scenario in ("all", "spec"):
        srows = spec_rows(args.arch, spec_k=args.spec_k)
        _print_rows(srows, "# spec scenario (self-speculative decoding, "
                           "draft-friendly snapped w4a8 weights, identical "
                           "traffic)")
        spec_x = _ratio(srows, "spec", "speculative", "sequential")
        sp = next(r for r in srows if r["config"] == "speculative")
        acc_rate = sp["spec_accepted"] / max(sp["spec_proposed"], 1)
        print(f"# spec decode: {spec_x:.2f}x tokens/tick with --spec-draft "
              f"planes:1 --spec-k {args.spec_k}, accept-rate "
              f"{acc_rate:.0%} (acceptance floor 1.3x)")
        out.update(spec_rows=srows, spec_accept_rate=acc_rate,
                   spec_tokens_per_tick_speedup=spec_x)
        all_rows += srows

    if args.scenario in ("all", "multi-tenant"):
        mrows = multi_tenant_rows(tier_dir=args.tier_dir)
        _print_rows(mrows, "# multi-tenant scenario (2 archs x 2 policies, "
                           "one shared pool, tiered prefix cache; cold run "
                           "then cold-restart reuse)")
        restart = [r for r in mrows if r["config"].startswith("restart:")]
        reuse_hits = sum(r["tier_hits_promoted"] for r in restart)
        reuse_skips = sum(r["prefill_skips"] for r in restart)
        attn = {p: next(r for r in mrows
                        if r["config"] == f"{p}:llama#0")
                for p in ("cold", "restart")}
        ttft_x = (attn["cold"]["ttft_p50_ticks"]
                  / max(attn["restart"]["ttft_p50_ticks"], 1e-9))
        print(f"# multi-tenant restart reuse: {reuse_hits} prefix pages "
              f"promoted from host/disk, {reuse_skips} prefills skipped "
              f"outright; attn-tenant p50 TTFT {ttft_x:.2f}x vs cold "
              f"(acceptance floor: >= 1 page reused without re-prefill)")
        out.update(multi_tenant_rows=mrows,
                   multi_tenant_restart_tier_hits=reuse_hits,
                   multi_tenant_restart_prefill_skips=reuse_skips,
                   multi_tenant_restart_ttft_p50_speedup=ttft_x)
        all_rows += mrows

    if args.scenario in ("all", "moe"):
        qrows = moe_rows()
        _print_rows(qrows, "# moe scenario (identical traffic, router "
                           "capacity headroom vs drop-forcing; utilization "
                           "= share of kept top-k assignments per expert)")
        tight = next(r for r in qrows if r["config"] == "capacity-tight")
        head = next(r for r in qrows if r["config"] == "capacity-headroom")
        print(f"# moe routing: drop-rate {tight['moe_drop_rate']:.1%} at "
              f"capacity_factor={tight['capacity_factor']} vs "
              f"{head['moe_drop_rate']:.1%} at headroom; expert util "
              f"[{head['moe_expert_util']}] (acceptance: headroom arm "
              f"drops nothing, tight arm drops > 0)")
        out.update(moe_rows=qrows,
                   moe_tight_drop_rate=tight["moe_drop_rate"],
                   moe_headroom_drop_rate=head["moe_drop_rate"])
        all_rows += qrows

    if args.scenario in ("all", "decode-attn"):
        attn_rows = decode_attn_rows()
        _print_rows(attn_rows, "# decode-attn micro-scenario (per-step "
                               "attention read path; interpret-mode wall "
                               "time + modeled pool traffic)")

        def _attn(cfg_, al):
            return next(r for r in attn_rows
                        if r["config"] == cfg_ and r["active_len"] == al)
        fused_x_1024 = (_attn("gather-full", 1024)["us_per_step"]
                        / _attn("fused", 1024)["us_per_step"])
        fused_bytes_x_1024 = (
            _attn("gather-full", 1024)["hbm_kv_bytes_per_step"]
            / _attn("fused", 1024)["hbm_kv_bytes_per_step"])
        print(f"# decode-attn @1024 active: fused {fused_x_1024:.2f}x faster "
              f"than the jitted gather (full width), {fused_bytes_x_1024:.2f}x "
              f"less pool traffic")
        out.update(decode_attn_rows=attn_rows,
                   decode_attn_fused_speedup_at_1024=fused_x_1024,
                   decode_attn_fused_bytes_ratio_at_1024=fused_bytes_x_1024)
        all_rows += attn_rows

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, default=str)
        print(f"# wrote {args.json}")
    return all_rows


if __name__ == "__main__":
    main()
