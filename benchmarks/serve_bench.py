"""Serving-layer bench: paged vs contiguous KV layout under mixed-length
traffic (docs/SERVING.md).

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch llama3.2-3b]

Reports tok/s for both layouts on identical traffic, jit signature counts
(the bucketing discipline), and page-pool utilization — the paged win is the
*capacity* column: the slab layout reserves slots*cache_len tokens up front,
the pool holds only what live requests actually cover.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models import transformer
from repro.models.common import ModelCtx


def _traffic(cfg, n, rng):
    return [Request(i, rng.integers(0, cfg.vocab,
                                    size=(int(rng.integers(2, 25)),)).astype(np.int32),
                    int(rng.integers(4, 13)))
            for i in range(n)]


def run(arch="llama3.2-3b", requests=12, slots=4, cache_len=128, page_size=16):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, policy="ternary")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    rows = []
    for paged in (True, False):
        srv = Server(cfg, sparams, slots=slots, cache_len=cache_len,
                     paged=paged, page_size=page_size,
                     ctx=ModelCtx(mode="serve"))
        for r in _traffic(cfg, requests, np.random.default_rng(0)):
            srv.submit(r)
        t0 = time.perf_counter()
        ticks = srv.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in srv.completed)
        live = max((int(np.sum(np.ceil((t + 1) / page_size)))
                    for t in srv.pos_trace if t.size), default=0)
        rows.append(dict(
            layout="paged" if paged else "contiguous",
            tok_s=toks / dt, ticks=ticks,
            jit_prefill=srv.compile_counts["prefill"],
            jit_decode=srv.compile_counts["decode"],
            kv_reserved_tokens=(srv.pt.usable_pages * page_size if paged
                                else slots * cache_len),
            kv_peak_live_pages=(live if paged else "-"),
        ))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args(argv)
    rows = run(args.arch, args.requests, args.slots, args.cache_len,
               args.page_size)
    print("# serve bench (mixed-length traffic, identical for both layouts)")
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(f"{r[k]:.1f}" if isinstance(r[k], float) else str(r[k])
                       for k in keys))
    return rows


if __name__ == "__main__":
    main()
