"""Fig. 5 analogue: per-precision cost breakdown of the paper's benchmark
convolution (R=S=3, C=M=128, W=H=16, output-stationary) as an im2col GEMM.

The paper reports energy/op of 35/67/405 fJ for binary/ternary/int8 and
observes *superlinear* growth with operand width. Energy is not measurable
here; the transferable observables are:

  bytes/op   operand traffic per MAC (the dominant energy proxy in CMOS —
             SRAM/HBM access energy dwarfs ALU energy, same argument the
             paper makes for its memory banking)
  t_mem      roofline memory seconds on TPU v5e for the same GEMM
  t_compute  roofline compute seconds (popcount-VPU vs int8-MXU paths)
  wall_us    measured CPU wall time of the packed jnp serve formulations

Expectation (checked in tests/test_benchmarks.py): bytes/op ratios
binary:ternary:int8 ~ 1:2:8 — the paper's superlinear energy curve is
reproduced by the traffic term (35->67 fJ is x1.9 for x2 bits; 67->405 is
x6 for x4 bits, superlinear because wider operands also lose the popcount
reduction tree).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16, PEAK_OPS_INT8

# the paper's Fig. 5 layer: R=S=3, C=M=128, W=H=16 -> im2col GEMM
R = S = 3
C = M = 128
W = H = 16
GM, GK, GN = W * H, R * S * C, M          # 256 x 1152 x 128
MACS = GM * GK * GN
OPS = 2 * MACS                            # a MAC counts as two ops (paper §V)

VPU_OPS = 4e12        # ~VPU elementwise ops/s per chip (8x128 lanes)


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []

    x_f = jnp.asarray(np.sign(rng.standard_normal((GM, GK))) + 0.0)
    w_f = jnp.asarray(np.sign(rng.standard_normal((GN, GK))) + 0.0)

    # --- binary: packed planes, XNOR+popcount --------------------------------
    xp, wp = pack.pack_binary(x_f), pack.pack_binary(w_f)
    bin_operand = xp.nbytes + wp.nbytes
    bin_bytes = bin_operand + GM * GN * 4
    f = jax.jit(lambda a, b: pack.binary_dot_words(a[:, None, :], b, GK))
    us = _time(f, xp, wp)
    rows.append(dict(
        precision="binary", bits=1, bytes=bin_bytes,
        operand_bytes_per_op=bin_operand / OPS,
        bytes_per_op=bin_bytes / OPS,
        t_mem_s=bin_bytes / HBM_BW,
        # popcount path: ~3 VPU ops per 32-MAC word
        t_compute_s=(MACS / 32 * 3) / VPU_OPS,
        wall_us=us, paper_fj_per_op=35.0))

    # --- ternary: two planes, gated-XNOR+popcount ----------------------------
    xt = jnp.asarray(rng.integers(-1, 2, (GM, GK)).astype(np.float32))
    wt = jnp.asarray(rng.integers(-1, 2, (GN, GK)).astype(np.float32))
    xm, xs = pack.pack_ternary(xt)
    wm, ws = pack.pack_ternary(wt)
    ter_operand = xm.nbytes * 2 + wm.nbytes * 2
    ter_bytes = ter_operand + GM * GN * 4
    f = jax.jit(lambda a, b, c, d: pack.ternary_dot_words(
        a[:, None, :], b[:, None, :], c, d))
    us = _time(f, xm, xs, wm, ws)
    rows.append(dict(
        precision="ternary", bits=2, bytes=ter_bytes,
        operand_bytes_per_op=ter_operand / OPS,
        bytes_per_op=ter_bytes / OPS,
        t_mem_s=ter_bytes / HBM_BW,
        t_compute_s=(MACS / 32 * 5) / VPU_OPS,   # 2 ANDs + XOR + 2 popcounts
        wall_us=us, paper_fj_per_op=67.0))

    # --- int8: MXU path -------------------------------------------------------
    xq = jnp.asarray(rng.integers(-127, 128, (GM, GK)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (GK, GN)), jnp.int8)
    i8_operand = xq.nbytes + wq.nbytes
    i8_bytes = i8_operand + GM * GN * 4
    f = jax.jit(lambda a, b: jax.lax.dot(a.astype(jnp.int32), b.astype(jnp.int32)))
    us = _time(f, xq, wq)
    rows.append(dict(
        precision="int8", bits=8, bytes=i8_bytes,
        operand_bytes_per_op=i8_operand / OPS,
        bytes_per_op=i8_bytes / OPS,
        t_mem_s=i8_bytes / HBM_BW,
        t_compute_s=OPS / PEAK_OPS_INT8,
        wall_us=us, paper_fj_per_op=405.0))

    # normalized columns (paper's superlinearity check)
    b0 = rows[0]["bytes_per_op"]
    o0 = rows[0]["operand_bytes_per_op"]
    for r in rows:
        r["bytes_per_op_norm"] = r["bytes_per_op"] / b0
        r["operand_norm"] = r["operand_bytes_per_op"] / o0
        r["paper_energy_norm"] = r["paper_fj_per_op"] / 35.0
    return rows


def main():
    rows = run()
    print("# energy_proxy (paper Fig.5: R=S=3, C=M=128, W=H=16)")
    print("precision,bits,bytes_per_op,operand_norm,bytes_norm,paper_energy_norm,"
          "t_mem_s,t_compute_s,wall_us")
    for r in rows:
        print(f"{r['precision']},{r['bits']},{r['bytes_per_op']:.4f},"
              f"{r['operand_norm']:.2f},"
              f"{r['bytes_per_op_norm']:.2f},{r['paper_energy_norm']:.2f},"
              f"{r['t_mem_s']:.3e},{r['t_compute_s']:.3e},{r['wall_us']:.0f}")
    return rows


if __name__ == "__main__":
    main()
