"""Render the EXPERIMENTS.md roofline tables from results/dryrun_*.json."""
from __future__ import annotations

import json
import sys


def render(path: str, title: str) -> str:
    d = json.load(open(path))
    rows = d["results"]
    out = [f"### {title} (cost scope: {d['cost_scope']}, "
           f"{'multi-pod 2x16x16' if d['multi_pod'] else 'single-pod 16x16'})",
           "",
           "| arch | shape | t_comp[s] | t_mem[s] | t_coll[s] | bound | "
           "useful | roofl.frac | args GiB/dev | temp GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r["memory_per_device"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['bottleneck'][:4]} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} | "
            f"{m.get('argument_size_in_bytes', 0)/2**30:.2f} | "
            f"{m.get('temp_size_in_bytes', 0)/2**30:.2f} |")
    if d.get("failures"):
        out.append("")
        out.append(f"FAILURES: {[(f['arch'], f['shape']) for f in d['failures']]}")
    return "\n".join(out)


if __name__ == "__main__":
    for p, t in [("results/dryrun_singlepod.json",
                  "Roofline, single pod (final config; §Perf baselines via flags)"),
                 ("results/dryrun_multipod.json", "Multi-pod dry-run")]:
        try:
            print(render(p, t))
            print()
        except FileNotFoundError:
            print(f"({p} not present yet)")
