"""Programmatic dry-run of one (arch x shape x mesh) cell — the API the
roofline study is built on. Works on this CPU container (512 fake devices).

    PYTHONPATH=src python examples/multipod_dryrun.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch.dryrun import calibrate_cost_scope, run_cell
from repro.launch.mesh import make_production_mesh

scope = calibrate_cost_scope(make_production_mesh(multi_pod=True))
out = run_cell("llama3.2-3b", "train_4k", multi_pod=True, cost_scope=scope)
print("\nJSON record:", {k: out[k] for k in
      ("arch", "shape", "mesh", "bottleneck", "roofline_fraction")})
