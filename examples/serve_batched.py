"""End-to-end driver (the paper's kind is inference): serve a real ~125M-param
model with batched requests through the continuous-batching server, with
ternary-packed weights.

    PYTHONPATH=src python examples/serve_batched.py [--full] [--contiguous]
                                                    [--sched]

--full uses the actual xlstm-125m config (125M params; a couple of minutes of
CPU for weight init + a few tokens/s decode). Default uses the reduced config
so the example finishes in seconds. The paged KV cache (docs/SERVING.md) is
on by default; --contiguous selects the per-slot slab reference layout;
--sched turns on the prefix-sharing + preemption scheduler (shared prompt
prefixes alias physical pages, and an oversubscribed pool swaps the
lowest-priority request to a host slab instead of rejecting work).
"""
import sys

from repro.launch import serve

args = ["--arch", "xlstm-125m", "--requests", "8", "--max-new", "12",
        "--slots", "4", "--policy", "w-ternary"]
if "--full" not in sys.argv:
    args.append("--reduced")
if "--contiguous" in sys.argv:
    args.append("--contiguous")
elif "--sched" in sys.argv:
    args += ["--prefix-share", "--preempt", "--temperature", "0.8"]
serve.main(args)
