"""The paper's central trade-off: energy/footprint vs accuracy per precision.

Trains the same reduced LM under five precision policies (QAT) and reports
final loss next to the packed-weight footprint — the software twin of
BrainTTA's Fig. 5 + Table I trade-off.

    PYTHONPATH=src python examples/mixed_precision_sweep.py
"""
import dataclasses
import os
import sys

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.qat_quality import run
from repro.configs import get_config
from repro.models import transformer

curves = run(steps=50)
print("\npolicy      final_loss   packed_MiB")
for pol, losses in curves.items():
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(), policy=pol)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    mib = sum(np.asarray(x).nbytes for x in jax.tree.leaves(sparams)) / 2**20
    print(f"{pol:10s}  {np.mean(losses[-5:]):10.4f}   {mib:8.2f}")
