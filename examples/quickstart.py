"""Quickstart: build a mixed-precision quantized LM, QAT-train it briefly,
pack it into BrainTTA bit-plane format, and serve a prompt.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry, transformer
from repro.models.common import ModelCtx, TRAIN
from repro.optim.adamw import adamw, apply_updates

# 1. pick an architecture and a precision policy (--arch / --precision in the
#    real drivers). "mixed" = the paper's recipe: int8 first/last, ternary body.
cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(), policy="mixed")
sp = transformer.build_specs(cfg)
params = transformer.init(jax.random.PRNGKey(0), cfg)
print(f"arch={cfg.name} policy={cfg.policy} "
      f"params={sum(x.size for x in jax.tree.leaves(params))/1e6:.2f}M")

# 2. a few QAT steps (straight-through estimators keep the master weights fp32)
opt = adamw(1e-3)
state = opt.init(params)
for step in range(20):
    batch = registry.make_batch(jax.random.fold_in(jax.random.PRNGKey(1), step),
                                cfg, 4, 32)
    (loss, _), grads = jax.value_and_grad(transformer.loss_fn, has_aux=True)(
        params, batch, sp, TRAIN)
    upd, state, _ = opt.update(grads, state, params)
    params = apply_updates(params, upd)
    if step % 5 == 0:
        print(f"  step {step:3d} loss {float(loss):.3f}")

# 3. pack for serving: ternary weights become 2 bit-planes (16 trits / word),
#    int8 layers become codes + scales — BrainTTA's storage format
sparams = transformer.pack_for_serve(params, cfg)
tb = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
sb = sum(np.asarray(x).nbytes for x in jax.tree.leaves(sparams))
print(f"packed: {tb/2**20:.2f} MiB -> {sb/2**20:.2f} MiB ({tb/sb:.1f}x)")

# 4. serve: prefill a prompt, decode greedily with the packed kernels' algebra
serve = ModelCtx(mode="serve")
prompt = jnp.asarray([[5, 42, 7, 99, 123, 4, 17, 56]], jnp.int32)
logits, cache = transformer.prefill(sparams, prompt, sp, serve, cache_len=32)
toks = [int(jnp.argmax(logits[0, -1]))]
for i in range(8):
    logits, cache = transformer.decode_step(
        sparams, cache, jnp.asarray([[toks[-1]]], jnp.int32),
        jnp.int32(prompt.shape[1] + i), sp, serve)
    toks.append(int(jnp.argmax(logits[0, 0])))
print("generated token ids:", toks)
