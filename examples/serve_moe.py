"""Expert-parallel MoE serving on a minimal two-device mesh.

    PYTHONPATH=src python examples/serve_moe.py [--dense]

Serves the reduced deepseek-moe config (4 routed experts, top-2, one shared
expert) on a forced two-CPU-device ("data", "model") = (1, 2) mesh: the
expert stacks shard over the model axis and each device runs only its two
local experts on their capacity-dispatched token slabs — the grouped expert
dispatch of docs/MOE.md. The server prints the routing telemetry
(moe_routed / moe_dropped / moe_expert_tokens) with the rest of its stats;
routing is replicated and deterministic, so the tokens AND the counters are
bit-identical to the dense-expert-vmap path (--dense re-runs with
--no-moe-ep so you can diff the two yourself).
"""
import os
import sys

# must be set before jax initializes: fake 2 CPU devices for the mesh
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

from repro.launch import serve

args = ["--arch", "deepseek-moe-16b", "--reduced", "--paged",
        "--mesh", "1,2", "--requests", "4", "--max-new", "8",
        "--slots", "2", "--cache-len", "64", "--page-size", "8"]
if "--dense" in sys.argv:
    args.append("--no-moe-ep")
serve.main(args)
