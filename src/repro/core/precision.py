"""Per-layer mixed-precision policy — the paper's central flexibility claim.

BrainTTA's motivation (§II-A): "some layers are more resilient to quantization
than others", so the architecture supports *mixed* precision — different
weight/activation bit-widths per layer, typically keeping the first and last
layers wide. A `PrecisionPolicy` assigns a `QuantSpec` pair (weights,
activations) to every *layer class* in a model, with first/last-layer
overrides, mirroring how a compiler would annotate the network graph for the
SoC.

Layer classes used by the model zoo:
  embed, attn_qkv, attn_out, ffn_up, ffn_down, moe_expert, moe_router,
  ssm_proj, lm_head
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from .quantize import QuantSpec, Precision

LAYER_CLASSES = (
    "embed", "attn_qkv", "attn_out", "ffn_up", "ffn_down",
    "moe_expert", "moe_router", "ssm_proj", "lm_head",
)

#: layer classes that stay high-precision no matter the policy (router logits
#: and embeddings are tiny but accuracy-critical — the paper's "sensitive
#: layers stay wide" rule).
ALWAYS_WIDE = ("moe_router", "embed")


@dataclasses.dataclass(frozen=True)
class LayerQuant:
    """Quantization of one layer: weights and activations may differ."""
    weights: QuantSpec = QuantSpec("none")
    acts: QuantSpec = QuantSpec("none")

    @property
    def tag(self) -> str:
        return f"w{self.weights.precision[:3]}/a{self.acts.precision[:3]}"


def _lq(w: Precision, a: Precision) -> LayerQuant:
    return LayerQuant(QuantSpec(w), QuantSpec(a))


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Maps layer classes to LayerQuant, with first/last layer overrides.

    `body` applies to every matmul layer class unless overridden in `per_class`.
    `first_last` overrides layers inside the first/last transformer block and
    the lm_head/embed (the classic mixed-precision recipe from the paper's
    conclusion: "mitigate accuracy loss in layers that are most adversely
    affected ... typically the first and last layer").
    """
    name: str
    body: LayerQuant
    first_last: LayerQuant = _lq("int8", "int8")
    per_class: Mapping[str, LayerQuant] = dataclasses.field(default_factory=dict)

    def lookup(self, layer_class: str, *, is_first: bool = False, is_last: bool = False) -> LayerQuant:
        if layer_class in ALWAYS_WIDE:
            return LayerQuant()
        if layer_class in self.per_class:
            return self.per_class[layer_class]
        if is_first or is_last:
            return self.first_last
        return self.body


# -- canonical policies (selectable via --precision) --------------------------

POLICIES: dict[str, PrecisionPolicy] = {
    # paper's three headline operating points, applied uniformly — the PURE
    # policies quantize first/last too (Table I single-precision columns);
    # "mixed" is the paper's accuracy recipe (first/last stay int8)
    "binary": PrecisionPolicy("binary", body=_lq("binary", "binary"),
                              first_last=_lq("binary", "binary")),
    "ternary": PrecisionPolicy("ternary", body=_lq("ternary", "ternary"),
                               first_last=_lq("ternary", "ternary")),
    "int8": PrecisionPolicy("int8", body=_lq("int8", "int8"),
                            first_last=_lq("int8", "int8")),
    # mixed: the recipe the paper advocates — int8 first/last, ternary body
    "mixed": PrecisionPolicy("mixed", body=_lq("ternary", "ternary")),
    # mixed w/a recipes (beyond the paper's matched pairs): weights in the
    # cheap packed format, activations int8 — the regime the mixed-precision
    # accelerator line targets (Bruschi'20, Zhao'19). Per-row requant
    # composes the two scales; the first/last layers stay full int8.
    "wt-a8": PrecisionPolicy("wt-a8", body=_lq("ternary", "int8")),
    "w4a8": PrecisionPolicy("w4a8", body=_lq("int4", "int8")),
    # heterogeneous per-layer-class demo: each layer class picks its own
    # operating point (the serve path resolves them per layer, not from a
    # global flag pair) — ffn_up tolerates s4 weights, attn_out keeps trits,
    # qkv stays int8; all activations int8 so the residual stream requants
    # uniformly.
    "het": PrecisionPolicy("het", body=_lq("ternary", "int8"), per_class={
        "ffn_up": _lq("int4", "int8"),
        "ffn_down": _lq("ternary", "int8"),
        "attn_qkv": _lq("int8", "int8"),
        "attn_out": _lq("ternary", "int8"),
        "moe_expert": _lq("int4", "int8"),
    }),
    # weight-only variants (useful for LLMs: activations stay bf16)
    "w-binary": PrecisionPolicy("w-binary", body=_lq("binary", "none"),
                                first_last=_lq("int8", "none")),
    "w-ternary": PrecisionPolicy("w-ternary", body=_lq("ternary", "none"),
                                 first_last=_lq("int8", "none")),
    "w-int4": PrecisionPolicy("w-int4", body=_lq("int4", "none"),
                              first_last=_lq("int8", "none")),
    "w-int8": PrecisionPolicy("w-int8", body=_lq("int8", "none")),
    # no quantization — the fp/bf16 baseline every comparison needs
    "none": PrecisionPolicy("none", body=LayerQuant(), first_last=LayerQuant()),
}


def policy_operating_points() -> set[tuple[str, str]]:
    """Every (wprec, aprec) pair the POLICIES table can assign to some layer
    — the registry-completeness tests regenerate their sweep from this, so
    a new policy entry automatically extends the coverage obligation on the
    dispatch registry."""
    pts = set()
    for pol in POLICIES.values():
        for lc in LAYER_CLASSES:
            for first, last in ((False, False), (True, False), (False, True)):
                lq = pol.lookup(lc, is_first=first, is_last=last)
                pts.add((lq.weights.precision, lq.acts.precision))
    return pts


def get_policy(name: str) -> PrecisionPolicy:
    try:
        return POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown precision policy {name!r}; have {sorted(POLICIES)}") from None
