"""Bit-plane packing — BrainTTA's v_C operands-per-word storage (§IV-B).

The SoC packs 32 binary / 16 ternary / 4 int8 operands into each 32-bit word
so a 1024-bit vector holds one vMAC input. On TPU the analogous layout packs
the *contraction* (K) axis of a GEMM into int32 words:

  binary : K/32 words, bit k of word j  = code of operand j*32+k
  ternary: two planes (mask, sign), each K/32 words of 1-bit fields
           (a trit is 2 bits *across planes*, matching v_C=16 per 32-bit
            word-pair of storage)
  int8   : native int8 arrays (4 per 32-bit word is the hardware's native
           byte layout already; XLA handles it)

Packing always happens along the LAST axis; callers move K last first.
K must be a multiple of 32 (pad upstream — model dims here are all
multiples of 128, cf. paper's "multiples of v_C for full utilization",
Table I flexibility rows).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32  # bits per packed word
NIBBLES = 8  # int4 codes per 32-bit word (v_C=8 for the s4 format)

#: total bit-planes of each plane-decomposable weight precision (two's
#: complement: plane 0 is the sign plane, coefficient -2^(b-1))
PLANE_BITS = {"int4": 4, "int8": 8}

#: K elements per unit of each packed leaf's storage axis — THE pack-factor
#: table every layer consults (`kernels.dispatch.tp_plan` for shard_map
#: compute, `launch.sharding` for device layout). A leaf absent here is
#: unpacked (one element per storage unit).
K_QUANTUM = {"w_packed": WORD, "w_mask": WORD, "w_sign": WORD,
             "w_q4": NIBBLES, "w_planes": WORD}


def shardable_words(units: int, n_shards: int) -> bool:
    """True iff a storage axis of `units` whole quanta (packed 32-operand
    words for the bit-plane formats, int8 codes for the 8-bit format) splits
    into `n_shards` equal whole-quantum shards.

    This is THE divisibility rule for tensor-parallel K-sharding of packed
    operands: a shard boundary may never fall inside a packed word (the
    XNOR/gated-XNOR word algebra contracts whole words), so sharding the
    packed axis of `w_packed`/`w_mask`/`w_sign` requires K to divide
    pack_factor(32) x n_shards. Both `launch.sharding` (device layout) and
    `kernels.dispatch` (shard_map compute) consult this one predicate so the
    two can never disagree about whether a leaf is K-shardable.
    """
    return n_shards > 0 and units % n_shards == 0


def _check_k(k: int) -> None:
    if k % WORD:
        raise ValueError(f"packing axis length {k} not a multiple of {WORD}")


def pack_bits(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack 0/1 codes (uint8, last axis = K) into int32 words (last axis K/32).

    Bit k of word j holds code[..., j*32+k] (little-endian within the word).
    """
    _check_k(codes.shape[-1])
    c = codes.reshape(*codes.shape[:-1], codes.shape[-1] // WORD, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    words = jnp.sum(c.astype(jnp.uint32) << shifts, axis=-1)
    return words.astype(jnp.uint32)


def unpack_bits(words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of pack_bits -> uint8 codes with last axis k."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * WORD)[..., :k].astype(jnp.uint8)


# -- binary ------------------------------------------------------------------

def pack_binary(values: jnp.ndarray) -> jnp.ndarray:
    """Pack {-1,+1} float values: bit=1 encodes +1."""
    return pack_bits((values >= 0).astype(jnp.uint8))


def unpack_binary(words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Unpack to {-1,+1} float32."""
    bits = unpack_bits(words, k)
    return jnp.where(bits == 1, 1.0, -1.0).astype(jnp.float32)


def unpack_pm1_i8(words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Unpack bit-plane words to ±1 int8 along a new last axis of length k.

    The canonical plane->operand decoder for the MXU formulations (jnp and
    Pallas tile bodies both call this — one unpack implementation total).
    """
    bits = unpack_bits(words, k)
    return bits.astype(jnp.int8) * 2 - 1


# -- ternary -----------------------------------------------------------------

def pack_ternary(values: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack {-1,0,+1} floats into (mask_words, sign_words) planes."""
    mask = (values != 0).astype(jnp.uint8)
    sign = (values < 0).astype(jnp.uint8)
    return pack_bits(mask), pack_bits(sign)


def unpack_ternary(mask_words: jnp.ndarray, sign_words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Unpack planes to {-1,0,+1} float32."""
    mask = unpack_bits(mask_words, k).astype(jnp.float32)
    sign = unpack_bits(sign_words, k)
    return mask * jnp.where(sign == 1, -1.0, 1.0)


def unpack_ternary_i8(mask_words: jnp.ndarray, sign_words: jnp.ndarray,
                      k: int) -> jnp.ndarray:
    """Unpack trit planes to {-1,0,+1} int8 (canonical MXU-path decoder)."""
    mask = unpack_bits(mask_words, k).astype(jnp.int8)
    sign = unpack_bits(sign_words, k).astype(jnp.int8)
    return mask * (1 - 2 * sign)


# -- int4 (s4 nibble codes, 8 per word) --------------------------------------

def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack s4 codes in [-8,7] (int dtype, last axis = K) into uint32 words.

    Nibble j of word i holds code[..., i*8+j] in two's complement
    (little-endian within the word), so K/8 words per row — v_C=8.
    """
    k = codes.shape[-1]
    if k % NIBBLES:
        raise ValueError(f"int4 packing axis length {k} not a multiple of {NIBBLES}")
    c = codes.astype(jnp.int32) & 0xF
    c = c.reshape(*codes.shape[:-1], k // NIBBLES, NIBBLES)
    shifts = jnp.arange(NIBBLES, dtype=jnp.uint32) * 4
    return jnp.sum(c.astype(jnp.uint32) << shifts, axis=-1).astype(jnp.uint32)


def unpack_int4_i8(words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Unpack s4 nibble words to int8 codes along a last axis of length k.

    The canonical word->operand decoder for the int4 MXU formulations — the
    jnp accumulator and the Pallas MacBody both call this, so jnp-vs-pallas
    equivalence stays an algebra check. Sign extension is arithmetic
    (nibble >= 8 => nibble - 16), keeping the whole path integer."""
    shifts = jnp.arange(NIBBLES, dtype=jnp.uint32) * 4
    nib = ((words[..., None] >> shifts) & jnp.uint32(0xF)).astype(jnp.int32)
    nib = nib.reshape(*words.shape[:-1], words.shape[-1] * NIBBLES)[..., :k]
    return jnp.where(nib >= 8, nib - 16, nib).astype(jnp.int8)


# -- bit-plane stacks (int4/int8 as shifted sums of binary planes) -----------
#
# Exact two's-complement decomposition of a b-bit code c:
#
#     c = -2^(b-1) * bit_{b-1} + sum_{j<b-1} 2^j * bit_j
#
# stored MSB-first along a NEW plane axis inserted before the last two axes,
# so a (N, K) code matrix becomes a (b, N, K/32) uint32 stack and an expert
# stack (E, N, K) becomes (E, b, N, K/32). MSB-first ordering makes plane
# truncation a leading slice `w_planes[:P]` with UNCHANGED per-plane
# coefficients — the storage trick self-speculative decoding exploits (a
# truncated-plane pass over the same weights is the draft model). The plane
# axis never touches the K storage axis, so K_QUANTUM["w_planes"] stays the
# 32-operand word quantum and the tensor-parallel shard rules apply verbatim.


def plane_coeffs(bits: int) -> tuple[int, ...]:
    """MSB-first per-plane coefficients of the b-bit two's-complement
    decomposition: (-2^(b-1), 2^(b-2), ..., 2, 1). Python ints — static in
    every jit trace, so truncated stacks keep their original coefficients."""
    if not 2 <= bits <= 8:
        raise ValueError(f"plane decomposition supports 2..8 bits, got {bits}")
    return (-(1 << (bits - 1)),) + tuple(
        1 << (bits - 1 - i) for i in range(1, bits))


def pack_planes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Decompose b-bit two's-complement codes (int dtype, last axis = K) into
    a stacked bit-plane tensor: uint32 (..., bits, N, K/32), MSB-first.

    Bit-exact inverse is `unpack_planes_i8(planes, k, bits)`; a leading
    slice `planes[..., :P, :, :]` is the truncated-plane approximation
    (floor(c / 2^(b-P)) * 2^(b-P), rounding toward -inf)."""
    coeffs = plane_coeffs(bits)          # validates bits
    del coeffs
    _check_k(codes.shape[-1])
    if codes.ndim < 2:
        raise ValueError("pack_planes needs at least a (N, K) matrix")
    field = codes.astype(jnp.int32) & ((1 << bits) - 1)   # b-bit 2c field
    planes = [pack_bits(((field >> (bits - 1 - i)) & 1).astype(jnp.uint8))
              for i in range(bits)]
    return jnp.stack(planes, axis=-3)


def unpack_planes_i8(planes: jnp.ndarray, k: int, bits: int) -> jnp.ndarray:
    """Compose a (possibly truncated) plane stack back to int8 codes.

    planes: uint32 (..., P, N, K/32) with P <= bits leading (MSB-first)
    planes of the ORIGINAL b-bit decomposition; k: unpacked K. P == bits
    reproduces the stored codes exactly (round-trip contract); P < bits
    gives the truncation floor(c / 2^(b-P)) * 2^(b-P). The canonical
    plane->operand decoder — the jnp accumulator and the Pallas MacBody
    both derive from the same coefficients, so jnp-vs-pallas equivalence
    stays an algebra check."""
    p_live = planes.shape[-3]
    coeffs = jnp.asarray(plane_coeffs(bits)[:p_live], jnp.int32)
    bitsmat = unpack_bits(planes, k).astype(jnp.int32)    # (..., P, N, k)
    vals = jnp.sum(bitsmat * coeffs[..., :, None, None], axis=-3)
    return vals.astype(jnp.int8)


# -- packed dot products (the XNOR/gated-XNOR algebra, §II-A) ----------------

def binary_dot_words(x_words: jnp.ndarray, w_words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Binary dot product over packed words: sum_i x_i * w_i, x,w in {-1,+1}.

    XNOR-popcount identity: matches = K - popcount(x ^ w);
    dot = matches - mismatches = K - 2*popcount(x ^ w).
    Contracts the last axis of both operands (word axis).
    """
    mismatch = jnp.sum(
        jax.lax.population_count(jnp.bitwise_xor(x_words, w_words)).astype(jnp.int32),
        axis=-1,
    )
    return jnp.int32(k) - 2 * mismatch


def ternary_dot_words(
    xm: jnp.ndarray, xs: jnp.ndarray, wm: jnp.ndarray, ws: jnp.ndarray
) -> jnp.ndarray:
    """Gated-XNOR dot product over packed trit planes (§II-A).

    active = xm & wm (both non-zero); within active lanes the product is
    +1 where signs agree, -1 where they differ:
        dot = popcount(active & ~(xs^ws)) - popcount(active & (xs^ws))
            = popcount(active) - 2*popcount(active & (xs^ws))
    """
    active = jnp.bitwise_and(xm, wm)
    disagree = jnp.bitwise_and(active, jnp.bitwise_xor(xs, ws))
    pc = lambda v: jnp.sum(jax.lax.population_count(v).astype(jnp.int32), axis=-1)
    return pc(active) - 2 * pc(disagree)
