"""Requantization — BrainTTA layer type 7 (§IV-A) and the "as early as
possible" principle of §IV-B.

The wide accumulator (int32 for int8 GEMMs, int16-equivalent for b/t popcount
sums) is rescaled back into the narrow operand format of the *next* layer.
In the SoC this is a vOPS instruction fused right after the vMAC; here it is
an epilogue fused into the GEMM kernels (see kernels/*.py) and, for the QAT
path, a float op.

Requantization for residual addition (layer type 6) requires both addends to
share a scale; `match_scales` produces the common scale and the two integer
rescale factors.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

from .quantize import Precision


@dataclasses.dataclass(frozen=True)
class RequantParams:
    """Per-output-channel affine requant: y = clip(round(acc * scale + bias))."""
    out_precision: Precision  # target format of the next layer's operands


def requantize(
    acc: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray | None,
    out_precision: Precision,
    ternary_threshold: float = 0.5,
) -> jnp.ndarray:
    """Rescale a wide accumulator into the narrow operand format.

    acc:   int32 (or float) accumulator, channels on the last axis.
    scale: per-channel (broadcastable) float scale.
    bias:  optional per-channel float bias (folded BN / layer bias).
    """
    y = acc.astype(jnp.float32) * scale
    if bias is not None:
        y = y + bias
    if out_precision == "binary":
        return jnp.where(y >= 0, 1.0, -1.0)
    if out_precision == "ternary":
        return jnp.where(y > ternary_threshold, 1.0, jnp.where(y < -ternary_threshold, -1.0, 0.0))
    if out_precision == "int4":
        return jnp.clip(jnp.round(y), -7, 7)
    if out_precision == "int8":
        return jnp.clip(jnp.round(y), -127, 127)
    return y  # "none": hand back the rescaled float (residual stream)


def match_scales(scale_a: jnp.ndarray, scale_b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Common scale + per-addend multipliers for residual addition (§IV-A).

    a*scale_a + b*scale_b == (a*ma + b*mb) * common, common = max(sa, sb).
    """
    common = jnp.maximum(scale_a, scale_b)
    return common, scale_a / common, scale_b / common
