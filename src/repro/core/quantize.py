"""Quantizers for BrainTTA's three operand precisions: binary, ternary, int8.

The paper (§II-A) restricts weights/activations to {-1,+1} (binary) or
{-1,0,+1} (ternary), or to int8. For *training* (which the edge SoC does not
do, but a pod framework must) we use straight-through-estimator (STE)
fake-quantization: the forward pass sees the quantized value, the backward
pass sees the identity (clipped). For *serving*, `repro.core.pack` converts
the quantized tensors into the bit-plane format the packed kernels consume.

All quantizers share the signature ``quantize(x, scale) -> q`` where ``q``
is float-typed but holds only representable values (fake-quant), plus an
integer-codes variant used by the packed/serve path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

Precision = Literal["binary", "ternary", "int4", "int8", "none"]

#: bits per operand for each precision (paper Table I / §IV-B: v_C = 32/16/4
#: operands per 32-bit word => 1/2/8 bits each; int4 is the beyond-paper
#: s4-codes point between ternary and int8).
BITS = {"binary": 1, "ternary": 2, "int4": 4, "int8": 8, "none": 16}

#: packing density: operands per 32-bit word (paper's v_C for a 32-bit lane).
PACK_FACTOR = {"binary": 32, "ternary": 16, "int4": 8, "int8": 4}


def _ste(fwd: jnp.ndarray, grad_path: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: forward `fwd`, gradient of `grad_path`."""
    return grad_path + jax.lax.stop_gradient(fwd - grad_path)


# ---------------------------------------------------------------------------
# binary {-1,+1}
# ---------------------------------------------------------------------------

def binarize(x: jnp.ndarray) -> jnp.ndarray:
    """sign(x) in {-1,+1} with STE on the clipped input (BinaryNet-style).

    Gradient is passed through only inside |x|<=1 (hard-tanh STE), which is
    the standard estimator for binary nets [Rastegari'16].
    """
    q = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    return _ste(q, jnp.clip(x, -1.0, 1.0))


# ---------------------------------------------------------------------------
# ternary {-1,0,+1}
# ---------------------------------------------------------------------------

def ternarize(x: jnp.ndarray, threshold: float = 0.05, axis=None) -> jnp.ndarray:
    """Symmetric-threshold ternarization with STE [GXNOR-Net].

    q = 0 when |x| <= t, else sign(x). `threshold` is relative to the mean
    absolute value over `axis` (None => per-tensor, matching common TWN
    practice). The serve-path activation prep passes axis=-1: a per-row
    threshold keeps each batched request's quantization independent of its
    neighbors' content — with a per-tensor threshold, continuous batching
    would let one request perturb another's logits.
    """
    t = threshold * jnp.mean(jnp.abs(x), axis=axis, keepdims=axis is not None) + 1e-8
    q = jnp.where(x > t, 1.0, jnp.where(x < -t, -1.0, 0.0)).astype(x.dtype)
    return _ste(q, jnp.clip(x, -1.0, 1.0))


# ---------------------------------------------------------------------------
# int8 (symmetric, per-channel scale)
# ---------------------------------------------------------------------------

def int8_scale(x: jnp.ndarray, axis=None) -> jnp.ndarray:
    """Symmetric per-channel scale: max|x| / 127 (axis=None => per-tensor)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return amax / 127.0 + 1e-12


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Fake-quant int8 with STE: round(x/s) clipped to [-127,127], times s."""
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0) * scale
    return _ste(q.astype(x.dtype), x)


def int8_codes(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Integer int8 codes for the serve path."""
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


# ---------------------------------------------------------------------------
# int4 (symmetric, per-channel scale; s4 codes clipped to ±7)
# ---------------------------------------------------------------------------

def int4_scale(x: jnp.ndarray, axis=None) -> jnp.ndarray:
    """Symmetric per-channel scale: max|x| / 7 (axis=None => per-tensor).

    The ±7 symmetric range (not the full two's-complement -8) keeps the codec
    sign-symmetric like the int8 path — dequant(q) = -dequant(-q) — so the
    serve requant algebra is identical across the integer precisions."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return amax / 7.0 + 1e-12


def quantize_int4(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Fake-quant int4 with STE: round(x/s) clipped to [-7,7], times s."""
    q = jnp.clip(jnp.round(x / scale), -7.0, 7.0) * scale
    return _ste(q.astype(x.dtype), x)


def int4_codes(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Integer s4 codes (held in int8 until `pack.pack_int4` nibble-packs)."""
    return jnp.clip(jnp.round(x / scale), -7, 7).astype(jnp.int8)


# ---------------------------------------------------------------------------
# unified fake-quant entry point
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How one tensor class (weights or activations of a layer) is quantized."""
    precision: Precision = "none"
    ternary_threshold: float = 0.05
    per_channel: bool = True  # int8 only; channel = last axis

    @property
    def bits(self) -> int:
        return BITS[self.precision]


def fake_quant(x: jnp.ndarray, spec: QuantSpec, scale_axis=None) -> jnp.ndarray:
    """STE fake-quantization per `spec` (training / QAT path).

    binary/ternary carry the XNOR-Net alpha scale (mean|x| over `scale_axis`;
    per-tensor when None) so the QAT forward matches the packed serve path's
    `w_scale`/`a_alpha` algebra — without it the quantized magnitudes collapse
    to +-1 and QAT gradients explode (measured gnorm 1e12 on the pure-ternary
    sweep; EXPERIMENTS.md Bench qat_quality).
    """
    if spec.precision == "none":
        return x
    if spec.precision == "binary":
        q = binarize(x)
        alpha = jax.lax.stop_gradient(
            jnp.mean(jnp.abs(x), axis=scale_axis, keepdims=scale_axis is not None))
        return q * alpha
    if spec.precision == "ternary":
        q = ternarize(x, spec.ternary_threshold)
        qa = jax.lax.stop_gradient(jnp.abs(q))
        num = jnp.sum(jnp.abs(x) * qa, axis=scale_axis,
                      keepdims=scale_axis is not None)
        den = jnp.sum(qa, axis=scale_axis, keepdims=scale_axis is not None) + 1e-6
        return q * jax.lax.stop_gradient(num / den)
    if spec.precision == "int8":
        axis = tuple(range(x.ndim - 1)) if spec.per_channel else None
        s = jax.lax.stop_gradient(int8_scale(x, axis=axis))
        return quantize_int8(x, s)
    if spec.precision == "int4":
        axis = tuple(range(x.ndim - 1)) if spec.per_channel else None
        s = jax.lax.stop_gradient(int4_scale(x, axis=axis))
        return quantize_int4(x, s)
    raise ValueError(f"unknown precision {spec.precision!r}")
