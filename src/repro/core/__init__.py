"""repro.core — BrainTTA's contribution as composable JAX modules.

Mixed-precision (binary/ternary/int8) quantization with bit-packed storage,
XNOR/gated-XNOR/int8 GEMM formulations, fused requantization, and a per-layer
precision policy. See DESIGN.md §2 for the TTA→TPU mapping.
"""
from . import pack, precision, qlinear, quantize, requant  # noqa: F401
from .precision import LayerQuant, PrecisionPolicy, get_policy, POLICIES  # noqa: F401
from .quantize import QuantSpec, fake_quant, BITS, PACK_FACTOR  # noqa: F401
