"""QuantizedLinear — BrainTTA's vMAC as a composable JAX module.

One module covers every matmul in the model zoo (QKV/O, FFN, experts, SSM
projections, LM head). It has three execution backends:

  mode="train"  QAT: STE fake-quant of weights/activations, bf16 MXU matmul.
                This is what `train_step` lowers; the SoC does not train, a
                pod framework must (DESIGN.md §2).
  mode="serve"  packed inference: weights stored in the packed format of
                `core.pack` (32/16/8 operands per word for
                binary/ternary/int4, int8 codes for 8-bit), activations
                quantized on the fly. The layer's `dispatch.OperatingPoint`
                (`op=`) selects the registered cell and its execution:
                  impl="popcount"  paper-faithful XNOR/gated-XNOR + popcount
                                   (VPU path on TPU)
                  impl="mxu"       beyond-paper: unpack packed planes to ±1
                                   int8 *in VMEM* and use the int8 MXU path —
                                   packed HBM storage, dense-rate compute.
                  backend="pallas" runs the Pallas TPU kernels registered in
                                   `repro.kernels.dispatch` (interpret-
                                   validated on CPU); "jnp" runs the same
                                   registry's XLA formulations.
                  tile             optional harness.Tile block override
                                   (else the per-cell TuneTable).
                Weight and activation precisions may differ per layer
                (mixed w/a cells — see docs/DISPATCH.md).

Weight layout (train): w[in, out] (+ optional expert axis in front).
Weight layout (serve): precision-dependent, produced by `pack_params`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import pack
from .precision import LayerQuant
from .quantize import (QuantSpec, binarize, fake_quant, int4_codes,
                       int4_scale, int8_codes, int8_scale, ternarize)

Params = dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class QLinearSpec:
    in_dim: int
    out_dim: int
    lq: LayerQuant = LayerQuant()
    use_bias: bool = False
    experts: int = 0           # 0 = dense; >0 = leading expert axis on weights
    name: str = "qlinear"
    #: tensor-parallel role of this layer on a ("data","model") mesh
    #: (Megatron pairing): "column" = out-dim sharded, no collective;
    #: "row" = packed-K sharded, one pre-requant int32 psum; "none" =
    #: replicated. Only consulted when the caller threads a TPSpec (serve
    #: mesh mode); train and single-device serve ignore it.
    parallel: str = "none"


# ---------------------------------------------------------------------------
# init (train layout)
# ---------------------------------------------------------------------------

def init(rng: jax.Array, spec: QLinearSpec, dtype=jnp.float32) -> Params:
    shape = (spec.in_dim, spec.out_dim)
    if spec.experts:
        shape = (spec.experts,) + shape
    scale = 1.0 / (spec.in_dim ** 0.5)
    p: Params = {"w": jax.random.normal(rng, shape, dtype) * scale}
    if spec.use_bias:
        bshape = (spec.experts, spec.out_dim) if spec.experts else (spec.out_dim,)
        p["b"] = jnp.zeros(bshape, dtype)
    return p


# ---------------------------------------------------------------------------
# train path (QAT)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste_attach(q_wire, w, alpha):
    """Forward: the packed-path value. Backward: straight-through to w
    (hard-tanh mask). Crucially there is NO full-precision forward value to
    gather — `q_wire + (ste - stop_grad(ste))` does not work because XLA will
    not simplify float `a - a` to 0, so the bf16 `ste` got gathered anyway
    (measured: identical 12.5 TB all-gather; see EXPERIMENTS.md §Perf B)."""
    return q_wire


def _ste_attach_fwd(q_wire, w, alpha):
    return q_wire, (w, alpha)


def _ste_attach_bwd(res, g):
    w, alpha = res
    return None, (g * alpha * (jnp.abs(w) <= 1.0)).astype(w.dtype), None


_ste_attach.defvjp(_ste_attach_fwd, _ste_attach_bwd)


def _packed_wire_weight(w: jnp.ndarray, spec: QLinearSpec) -> jnp.ndarray:
    """QAT weight whose *value* flows through the packed bit-plane format.

    §Perf B (beyond paper, built from the paper's own format): under FSDP,
    XLA all-gathers the weight at every use — in bf16 that wire dominates
    large-model training. The QAT forward only needs the *quantized* weight,
    so its value is computed from `core.pack` planes pinned replicated-over-
    data: GSPMD must place the data-axis all-gather on the 1/2/8-bit planes
    (16x/8x/2x less wire than bf16). The STE gradient reaches the sharded
    master weight through `_ste_attach` (custom_vjp), so no full-precision
    forward tensor ever exists to be gathered."""
    from jax.sharding import PartitionSpec as P
    prec = spec.lq.weights.precision

    def rep(t):
        """Pin replicated-over-data (out-dim stays on model) — forces the
        FSDP all-gather HERE, on the packed planes. No-op without a mesh."""
        try:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is None or "model" not in (mesh.axis_names or ()):
                return t
            return jax.lax.with_sharding_constraint(
                t, P(*([None] * (t.ndim - 1)), "model"))
        except Exception:
            return t

    wq = jax.lax.stop_gradient(w)
    if prec == "ternary":
        q = jax.lax.stop_gradient(ternarize(wq, spec.lq.weights.ternary_threshold))
        qa = jnp.abs(q)
        alpha = (jnp.sum(jnp.abs(wq) * qa, axis=-2, keepdims=True)
                 / (jnp.sum(qa, axis=-2, keepdims=True) + 1e-6))
        m, sgn = pack.pack_ternary(jnp.swapaxes(q, -1, -2))  # pack along in-dim
        q_wire = jnp.swapaxes(pack.unpack_ternary(
            rep(m), rep(sgn), w.shape[-2]), -1, -2) * alpha
        q_wire = q_wire.astype(jnp.bfloat16)
    elif prec == "binary":
        q = jax.lax.stop_gradient(binarize(wq))
        alpha = jnp.mean(jnp.abs(wq), axis=-2, keepdims=True)
        words = pack.pack_binary(jnp.swapaxes(q, -1, -2))
        q_wire = (jnp.swapaxes(pack.unpack_binary(
            rep(words), w.shape[-2]), -1, -2) * alpha).astype(jnp.bfloat16)
    elif prec == "int8":
        axis = tuple(range(w.ndim - 1))
        sc = int8_scale(wq, axis=axis)
        codes = rep(int8_codes(wq, sc))
        q_wire = codes.astype(jnp.float32) * sc
        alpha = jnp.ones((), w.dtype)
    else:
        return fake_quant(w, spec.lq.weights, scale_axis=-2)
    return _ste_attach(q_wire, w, jax.lax.stop_gradient(alpha))


def _apply_train(p: Params, x: jnp.ndarray, spec: QLinearSpec,
                 wire: str = "dense") -> jnp.ndarray:
    # keep the master dtype through fake-quant: upcasting to f32 here made
    # every FSDP weight gather (and the STE backward reshard) move 2x the
    # bytes — nemotron-340b train: 3.7 TiB f32(18432,18432) gathers
    # (EXPERIMENTS.md §Perf B iter-5)
    wf = p["w"]
    if wire == "packed" and not spec.experts and wf.shape[-2] % 32 == 0:
        w = _packed_wire_weight(wf, spec).astype(x.dtype)
    else:
        # alpha per out-channel (reduce the in-dim) == serve w_scale algebra
        w = fake_quant(wf, spec.lq.weights, scale_axis=-2).astype(x.dtype)
    # name the gathered+quantized weight so the remat policy can SAVE it:
    # re-gathering weights during backward recompute tripled the FSDP
    # all-gather volume (§Perf B iter-6)
    from jax.ad_checkpoint import checkpoint_name
    w = checkpoint_name(w, "qweight")
    xq = fake_quant(x, spec.lq.acts, scale_axis=-1)  # per-row a_alpha
    if spec.experts:
        y = jnp.einsum("e...k,ekn->e...n", xq, w)
    else:
        y = xq @ w
    if "b" in p:
        b = p["b"]
        y = y + (b[:, None, :] if spec.experts and b.ndim == 2 else b)
    return y


# ---------------------------------------------------------------------------
# serve layout: pack_params + spec tree for the dry-run
# ---------------------------------------------------------------------------

def pack_params(p: Params, spec: QLinearSpec) -> Params:
    """Convert train-layout params to the packed serve layout.

    binary : w_packed  uint32[(E,) out, in/32]     (bit = +1)
             w_scale   f32[(E,) out]               (XNOR-Net per-channel alpha)
    ternary: w_mask/w_sign uint32[(E,) out, in/32]
             w_scale   f32[(E,) out]
    int4   : w_q4      uint32[(E,) out, in/8]      (s4 nibble codes, v_C=8)
             w_scale   f32[(E,) out]
    int8   : w_q       int8[(E,) in, out]
             w_scale   f32[(E,) out]
    int4/int8 weights with int8 acts additionally carry the stacked
    bit-plane twin of the same codes (word-aligned in_dim only):
             w_planes  uint32[(E,) bits, out, in/32]  (MSB-first 2c planes)
    feeding the impl="planes" cells and their truncated-plane drafts.
    none   : w         bf16 (dense weights, cast)
    `a_scale` (f32 scalar) is a calibrated activation scale for int8 acts.
    Weight and activation precisions are independent (mixed w/a operating
    points): the weight layout above composes with whatever `a_scale` the
    activation precision needs.
    """
    w = p["w"].astype(jnp.float32)
    prec = spec.lq.weights.precision
    out: Params = {}
    # channel-last -> put out_dim first for the packed (K-last) layouts
    wt = jnp.swapaxes(w, -1, -2)  # (E,) out, in
    if prec == "binary":
        out["w_packed"] = pack.pack_binary(jnp.sign(wt) + (wt == 0))
        out["w_scale"] = jnp.mean(jnp.abs(wt), axis=-1)
    elif prec == "ternary":
        q = ternarize(wt, spec.lq.weights.ternary_threshold)
        m, s = pack.pack_ternary(jax.lax.stop_gradient(q))
        out["w_mask"], out["w_sign"] = m, s
        nz = jnp.sum(jnp.abs(q), axis=-1) + 1e-6
        out["w_scale"] = jnp.sum(jnp.abs(wt) * jnp.abs(q), axis=-1) / nz
    elif prec == "int4":
        s = int4_scale(wt, axis=-1)            # per-out-channel, reduce in
        codes = int4_codes(wt, s)
        out["w_q4"] = pack.pack_int4(codes)
        if spec.lq.acts.precision == "int8" and spec.in_dim % pack.WORD == 0:
            # stacked bit-plane twin of the SAME codes (plane-composed cells
            # + truncated-plane speculative drafts); word-aligned K only
            out["w_planes"] = pack.pack_planes(codes, pack.PLANE_BITS[prec])
        out["w_scale"] = jnp.squeeze(s, axis=-1)
    elif prec == "int8":
        s = int8_scale(w, axis=(w.ndim - 2,))  # reduce in_dim, keep experts
        codes = int8_codes(w, s)
        out["w_q"] = codes
        if spec.lq.acts.precision == "int8" and spec.in_dim % pack.WORD == 0:
            out["w_planes"] = pack.pack_planes(
                jnp.swapaxes(codes, -1, -2), pack.PLANE_BITS[prec])
        out["w_scale"] = jnp.squeeze(s, axis=w.ndim - 2)
    else:
        out["w"] = w.astype(jnp.bfloat16)
    if spec.lq.acts.precision == "int8":
        out["a_scale"] = jnp.float32(0.05)  # calibration constant
    if "b" in p:
        out["b"] = p["b"].astype(jnp.float32)
    return out


def serve_param_shapes(spec: QLinearSpec) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct tree of the serve layout (dry-run, no allocation)."""
    e = (spec.experts,) if spec.experts else ()
    k, n = spec.in_dim, spec.out_dim
    prec = spec.lq.weights.precision
    sd = jax.ShapeDtypeStruct
    out: dict[str, jax.ShapeDtypeStruct] = {}
    if prec == "binary":
        out["w_packed"] = sd(e + (n, k // 32), jnp.uint32)
        out["w_scale"] = sd(e + (n,), jnp.float32)
    elif prec == "ternary":
        out["w_mask"] = sd(e + (n, k // 32), jnp.uint32)
        out["w_sign"] = sd(e + (n, k // 32), jnp.uint32)
        out["w_scale"] = sd(e + (n,), jnp.float32)
    elif prec == "int4":
        out["w_q4"] = sd(e + (n, k // pack.NIBBLES), jnp.uint32)
        if spec.lq.acts.precision == "int8" and k % pack.WORD == 0:
            out["w_planes"] = sd(e + (pack.PLANE_BITS[prec], n, k // pack.WORD),
                                 jnp.uint32)
        out["w_scale"] = sd(e + (n,), jnp.float32)
    elif prec == "int8":
        out["w_q"] = sd(e + (k, n), jnp.int8)
        if spec.lq.acts.precision == "int8" and k % pack.WORD == 0:
            out["w_planes"] = sd(e + (pack.PLANE_BITS[prec], n, k // pack.WORD),
                                 jnp.uint32)
        out["w_scale"] = sd(e + (n,), jnp.float32)
    else:
        out["w"] = sd(e + (k, n), jnp.bfloat16)
    if spec.lq.acts.precision == "int8":
        out["a_scale"] = sd((), jnp.float32)
    if spec.use_bias:
        out["b"] = sd(e + (n,) if e else (n,), jnp.float32)
    return out


# ---------------------------------------------------------------------------
# serve path — one dispatch into the precision-keyed GEMM registry
# ---------------------------------------------------------------------------

def apply(p: Params, x: jnp.ndarray, spec: QLinearSpec, *,
          mode: str = "train", op=None, impl: str | None = None,
          backend: str | None = None, wire: str = "dense",
          tp=None, ep=None) -> jnp.ndarray:
    """Apply the quantized linear. See module docstring for modes.

    Serve mode routes every operating point through
    `repro.kernels.dispatch.qgemm` — the single owner of activation
    packing, expert vmap and the fused bias/requant epilogue for both the
    jnp and Pallas backends. `op` (a `dispatch.OperatingPoint`) names the
    layer's operating point — precisions from the policy's LayerQuant,
    formulation/backend/tile from the execution context; None derives it
    from the spec plus the legacy `impl=`/`backend=` string kwargs. `tp`
    (a `dispatch.TPSpec`) runs the GEMM under shard_map in the layer's
    `spec.parallel` role (tensor-parallel serve); `ep` (a
    `dispatch.EPSpec`) runs expert stacks via the grouped expert-parallel
    dispatch instead of the replicated dense vmap."""
    if mode == "train":
        return _apply_train(p, x, spec, wire)
    if mode != "serve":
        raise ValueError(f"mode={mode!r}")
    from repro.kernels.dispatch import qgemm   # deferred: core must not pull
    return qgemm(p, x, spec, op, impl=impl, backend=backend,  # pallas at import
                 tp=tp, ep=ep, parallel=spec.parallel)
