"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch × shape × mesh) cell — all in seconds:

    compute    = HLO_FLOPs      / (chips × 197e12)          [bf16 MXU peak]
    memory     = HLO_bytes      / (chips × 819e9)           [HBM BW]
    collective = collective_B   / (chips × 50e9)            [ICI link BW]

HLO_FLOPs / bytes come from compiled.cost_analysis(). collective bytes are
parsed out of the HLO text: the result-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute op (per-kind
breakdown kept; replica-group sizes recorded to attribute pod-axis traffic).

Also derives MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE for train;
2·N·D for prefill; 2·N_active·B for decode) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste shows up here.
"""
from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.1 = bf16[16,1024]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_TUPLE_RE = re.compile(r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES)
                       + r")(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of collective ops, by kind, plus group-size stats."""
    per_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            per_kind[kind] += _shape_bytes(dtype, dims)
            count[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            inner, kind = m.groups()
            for dm in _SHAPE_RE.finditer(inner):
                per_kind[kind] += _shape_bytes(*dm.groups())
            count[kind] += 1
    total = sum(per_kind.values())
    return {"total": total, "per_kind": per_kind, "count": count}


def model_flops(cfg: ArchConfig, shape: ShapeConfig | str) -> float:
    """Analytic useful FLOPs per step (the numerator of the useful ratio)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_act * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_act * d
    return 2.0 * n_act * shape.global_batch        # decode: one token


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_detail: dict
    model_fl: float
    memory_per_device: dict
    cost_scope: str = "global"   # "global": divide by chips; "per_device": don't

    @property
    def _div(self) -> int:
        return self.chips if self.cost_scope == "global" else 1

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self._div * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self._div * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self._div * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (hlo is per-device under SPMD)."""
        global_hlo = self.hlo_flops * (self.chips if self.cost_scope == "per_device" else 1)
        return self.model_fl / max(global_hlo, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time: how close the cell is to the
        (compute) roofline given its dominant term."""
        t_useful = self.model_fl / (self.chips * PEAK_FLOPS_BF16)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / max(t_bound, 1e-12)

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes, "coll_detail": self.coll_detail,
            "model_flops": self.model_fl,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "cost_scope": self.cost_scope,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_per_device": self.memory_per_device,
        }


def analyse(arch: str, shape: str, mesh_name: str, chips: int, compiled,
            cfg: ArchConfig, cost_scope: str = "global") -> Roofline:
    """Roofline terms via the trip-count-aware HLO cost model (hlo_cost.py).

    XLA's own cost_analysis() counts scan bodies once (see hlo_cost docstring)
    so it is recorded only as `xla_raw` for reference."""
    from . import hlo_cost
    xla = compiled.cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    hlo = compiled.as_text()
    cost = hlo_cost.analyze_text(hlo)
    hlo_flops = cost.flops
    hlo_bytes = cost.bytes
    coll = {"total": cost.coll_bytes, "per_kind": dict(cost.coll),
            "count": dict(cost.coll_count),
            "xla_raw": {"flops": float(xla.get("flops", 0.0)),
                        "bytes": float(xla.get("bytes accessed", 0.0))}}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[f] = int(getattr(ma, f, 0))
        mem["total_nonalias"] = (mem.get("argument_size_in_bytes", 0)
                                 + mem.get("output_size_in_bytes", 0)
                                 + mem.get("temp_size_in_bytes", 0)
                                 - mem.get("alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(arch, shape, mesh_name, chips, hlo_flops, hlo_bytes,
                    float(coll["total"]), coll, model_flops(cfg, shape), mem,
                    cost_scope)
