"""Tiered prefix cache: device → host → disk retention of indexed KV pages.

The base `PageTable` frees an indexed page the moment its refcount hits
zero — a prefix computed once is gone as soon as its last owner retires, so
a later identical prompt (or a restarted server) pays full prefill again.
This module keeps prefix pages alive past refcount 0 across three tiers:

  * **device** — `TieredPageTable` parks refcount-0 indexed pages in an LRU
    set instead of freeing them. They stay mappable through the share index
    (a later `admit_shared` hit re-admits them at zero cost) but are
    reclaimable on demand: allocation evicts the LRU parked page when the
    free list runs dry, so the tier never blocks real work. An optional
    watermark bounds the parked set continuously.
  * **host** — eviction demotes the page's bytes to a host-side `PageStore`
    slab (same numpy-image mechanism as preemption swap), keyed by the
    page's exact prefix chain. A bounded LRU, like the device tier.
  * **disk** — host overflow (and an explicit `flush()`, e.g. at clean
    shutdown) demotes slabs to an on-disk directory, one file per page,
    so a *restarted* server re-admits previously seen prefixes without
    re-prefilling.

Content addressing: a page's store key is `(covered, rolling_hash, chain)`
where `chain` is the concatenation of every ancestor key's verbatim bytes
(namespace included) up to and including its own — the flat equivalent of
the share index's parent-physical-page chaining, which cannot survive a
restart (physical ids are meaningless across processes). Both the store and
the probe compute the chain from the same `prefix_keys` material, so a hit
proves the full token prefix (and the model namespace) matches verbatim;
the 64-bit hash in the filename is only a prefilter.

Crash consistency: a disk slab is written to a temp file and atomically
renamed into place, and carries a CRC-32 over its payload; a torn or
corrupted slab fails the checksum on load and is deleted and counted
(`corrupt_dropped`) rather than served. A benign filename collision
(checksum passes, chain differs) is a miss, not corruption.

Exactness: a parked page is in no slot's table row, so no decode write can
reach it (writes land via table rows only); its bytes stay exactly what the
share index key promises. Demotion gathers the whole page including bytes
past the key's coverage (a former owner's decode tail); promotion restores
them unchanged, and readers mask validity by position exactly as they do
for freshly shared pages — the token-exactness argument is unchanged from
plain prefix sharing. See docs/SERVING.md §Tiered prefix cache.
"""
from __future__ import annotations

import os
import pickle
import struct
import zlib
from collections import OrderedDict
from pathlib import Path

from repro.launch.kv_cache import NULL_PAGE, PageTable

_MAGIC = b"KVS1"


def _slab_name(key) -> str:
    covered, h, chain = key
    return f"{int(covered)}-{int(h):016x}-{zlib.crc32(chain):08x}.slab"


class PageStore:
    """Host + disk slab store for demoted prefix pages.

    `put`/`get` speak store keys `(covered, rolling_hash, chain_bytes)` and
    numpy page-image pytrees (`kv_cache.gather_pages`). The host tier is a
    bounded LRU dict; overflow demotes the oldest entry to `disk_dir` (or
    drops it when no disk tier is configured). `get` never promotes back
    into the host tier — a hit's next stop is the device pool anyway.
    """

    def __init__(self, host_capacity: int = 64,
                 disk_dir: str | os.PathLike | None = None):
        self.host_capacity = int(host_capacity)
        self.disk_dir = Path(disk_dir) if disk_dir is not None else None
        if self.disk_dir is not None:
            self.disk_dir.mkdir(parents=True, exist_ok=True)
        self._host: OrderedDict = OrderedDict()
        self.stats = {"host_hits": 0, "disk_hits": 0, "misses": 0,
                      "disk_writes": 0, "dropped": 0, "corrupt_dropped": 0}

    def __len__(self) -> int:
        return len(self._host)

    def put(self, key, image):
        """Store a page image under its content key; spill LRU overflow to
        disk. Idempotent per key (content-addressed: same key => same
        bytes, so last-writer-wins is harmless)."""
        self._host[key] = image
        self._host.move_to_end(key)
        while len(self._host) > self.host_capacity:
            old_key, old_img = self._host.popitem(last=False)
            self._spill(old_key, old_img)

    def get(self, key):
        """Look `key` up across tiers: returns `(image, tier)` with tier in
        {"host", "disk"}, or `(None, None)` on a miss. A host hit stays in
        the host tier (refreshed); a disk hit is read, verified, and left
        on disk."""
        img = self._host.get(key)
        if img is not None:
            self._host.move_to_end(key)
            self.stats["host_hits"] += 1
            return img, "host"
        img = self._disk_read(key)
        if img is not None:
            self.stats["disk_hits"] += 1
            return img, "disk"
        self.stats["misses"] += 1
        return None, None

    def flush(self):
        """Demote every host-tier slab to disk (clean-shutdown path: state
        that should survive the process must reach the disk tier)."""
        while self._host:
            key, img = self._host.popitem(last=False)
            self._spill(key, img)

    # -- disk tier -------------------------------------------------------------

    def _spill(self, key, image):
        if self.disk_dir is None:
            self.stats["dropped"] += 1
            return
        blob = pickle.dumps({"chain": key[2], "image": image},
                            protocol=pickle.HIGHEST_PROTOCOL)
        path = self.disk_dir / _slab_name(key)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "wb") as f:
            f.write(_MAGIC)
            f.write(struct.pack("<II", zlib.crc32(blob), len(blob)))
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)       # atomic: readers see old bytes or new
        self.stats["disk_writes"] += 1

    def _disk_read(self, key):
        if self.disk_dir is None:
            return None
        path = self.disk_dir / _slab_name(key)
        try:
            raw = path.read_bytes()
        except OSError:
            return None
        ok = len(raw) >= 12 and raw[:4] == _MAGIC
        if ok:
            crc, n = struct.unpack("<II", raw[4:12])
            blob = raw[12:]
            ok = len(blob) == n and zlib.crc32(blob) == crc
        if not ok:
            # torn or corrupted slab (partial write survived a crash, or
            # bit rot): drop it rather than deserialize garbage
            path.unlink(missing_ok=True)
            self.stats["corrupt_dropped"] += 1
            return None
        rec = pickle.loads(blob)
        if rec["chain"] != key[2]:
            return None             # benign filename collision: just a miss
        return rec["image"]


class TieredPageTable(PageTable):
    """`PageTable` whose indexed pages survive refcount 0.

    A released indexed page parks in a device-resident LRU (`_cached`)
    instead of returning to the free list; it stays findable through the
    share index, so the next identical prefix maps it for free (a
    *device-tier hit*, counted in `tier_stats`). Allocation pressure evicts
    parked pages LRU-first — demoting their bytes to `store` when one is
    configured — so `free_pages` counts parked pages as available and every
    admission-budget invariant of the base class keeps holding.

    Namespaces: `_current_ns` (stamped by `SlotView` on index-writing calls,
    or set once by a single-tenant server) records which tenant's device
    cache pool a page's bytes live in; the matching registered demoter
    gathers from that pool at eviction. Chains: `_page_chain[p]` accumulates
    the verbatim key bytes root→p at registration, giving eviction the
    page's restart-stable store key.

    `adopt` is the promotion inverse: the serving layer allocates a page for
    a store hit, registers it under the probing request's `(parent, key)`,
    scatters the slab bytes in, and the page starts life parked at
    refcount 0 — indistinguishable from a page whose last owner just
    retired.
    """

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 max_pages_per_slot: int, *, store: PageStore | None = None,
                 watermark: int = 0):
        super().__init__(num_pages, page_size, slots, max_pages_per_slot)
        self.store = store
        self.watermark = int(watermark)   # max parked pages; 0 = unbounded
        self._cached: OrderedDict = OrderedDict()   # page -> True (LRU)
        self._page_ns: dict[int, bytes] = {}
        self._page_chain: dict[int, bytes] = {}
        self._demoters: dict = {}
        self._current_ns = b""
        self._pinned: frozenset = frozenset()
        self.tier_stats = {"device_hits": 0, "evictions": 0, "demotions": 0,
                           "promotions": 0, "cached_peak": 0}

    def register_demoter(self, namespace: bytes, gather_fn):
        """`gather_fn(page_id) -> page image` for pages indexed under
        `namespace` (each tenant's pages live in its own device cache pool,
        so eviction must gather from the right one)."""
        self._demoters[bytes(namespace)] = gather_fn

    def is_cached(self, page: int) -> bool:
        return page in self._cached

    @property
    def cached_pages(self) -> int:
        return len(self._cached)

    @property
    def free_pages(self) -> int:
        # parked pages are reclaimable on demand (eviction below), so they
        # count as free for every admission/extend budget check
        return len(self._free) + len(self._cached)

    def stats(self) -> dict:
        out = super().stats()
        out["cached_pages"] = len(self._cached)
        return out

    def free_pages_for(self, keys) -> int:
        """Effective page supply for an admission probing `keys`: parked
        pages that the probe HITS are not supply — they will be mapped, not
        reclaimed — so they must come off the `free_pages` optimism. The
        serving layer's admission test uses this instead of `free_pages`
        whenever it holds prefix keys."""
        hits = self.lookup_keys(keys) if keys else []
        pinned = sum(1 for p in hits if p is not None and p in self._cached)
        return self.free_pages - pinned

    # -- base-class hook overrides ---------------------------------------------

    def admit_shared(self, slot: int, n_tokens: int, keys, *,
                     defer_index: bool = False):
        # pin the probe's parked hits for the duration: the miss allocations
        # below may evict, and evicting a page this very admission is about
        # to map would hand its id to the allocator mid-flight
        hits = self.lookup_keys(keys)
        pinned = frozenset(p for p in hits
                           if p is not None and p in self._cached)
        misses = sum(1 for p in hits if p is None)
        if self.free_pages - len(pinned) < misses:
            raise RuntimeError(
                f"page pool exhausted: want {misses}, free "
                f"{self.free_pages - len(pinned)} (net of parked hits)")
        self._pinned = pinned
        try:
            return super().admit_shared(slot, n_tokens, keys,
                                        defer_index=defer_index)
        finally:
            self._pinned = frozenset()

    def _register_key(self, parent, key, page: int):
        super()._register_key(parent, key, page)
        self._page_ns[page] = self._current_ns
        self._page_chain[page] = self._page_chain.get(parent, b"") + key[2]

    def _drop_page(self, page: int) -> bool:
        self.refcount[page] -= 1
        if self.refcount[page] > 0:
            return False
        if page in self._page_key:
            # indexed: park in the device tier instead of freeing
            self._cached[page] = True
            self._cached.move_to_end(page)
            self.tier_stats["cached_peak"] = max(
                self.tier_stats["cached_peak"], len(self._cached))
            if self.watermark:
                while len(self._cached) > self.watermark:
                    self._evict_one()
            return False
        self._free.append(int(page))
        return True

    def _map_page(self, slot: int, page: int):
        if page in self._cached:    # device-tier hit: page re-enters service
            del self._cached[page]
            self.tier_stats["device_hits"] += 1
        super()._map_page(slot, page)

    def _release(self, slot: int):
        # park child pages before their parents (reverse table order) so LRU
        # eviction takes leaves first and the surviving parked chain stays
        # reachable through the share index as long as possible
        freed = [int(p) for p in self.table[slot, : self.held[slot]][::-1]
                 if self._drop_page(p)]
        self.table[slot] = NULL_PAGE
        self.held[slot] = 0
        self.tokens[slot] = 0
        self.active[slot] = False
        return freed

    def _take_page(self) -> int:
        if not self._free and self._cached:
            self._evict_one()
        return super()._take_page()

    def _alloc(self, slot: int, n_pages: int):
        while len(self._free) < n_pages and self._cached:
            self._evict_one()
        return super()._alloc(slot, n_pages)

    # -- tier transitions ------------------------------------------------------

    def _evict_one(self):
        """Evict the LRU parked page: demote its bytes to the store (when
        both a store and this namespace's demoter exist), drop its share-
        index entry, and return the physical page to the free list."""
        page = next((p for p in self._cached if p not in self._pinned), None)
        if page is None:
            raise RuntimeError("page pool exhausted: every parked page is "
                               "pinned by an in-flight admission")
        del self._cached[page]
        ns = self._page_ns.pop(page, b"")
        chain = self._page_chain.pop(page, None)
        pk = self._page_key.pop(page, None)
        if pk is not None:
            self._index.pop(pk, None)
            gather = self._demoters.get(ns)
            if self.store is not None and gather is not None and chain is not None:
                covered, h = pk[1][0], pk[1][1]
                self.store.put((covered, h, chain), gather(page))
                self.tier_stats["demotions"] += 1
        self.refcount[page] = 0
        self._free.append(int(page))
        self.tier_stats["evictions"] += 1

    def adopt(self, parent, key, chain: bytes, namespace: bytes = b"") -> int:
        """Materialize a store hit: allocate a page, register it under
        `(parent, key)` with the given chain/namespace, and park it at
        refcount 0. The caller must scatter the slab bytes into the page
        BEFORE anything can map it (single-threaded serving: the admission
        that probed the store does both back-to-back)."""
        self._current_ns = bytes(namespace)
        page = self._take_page()
        self.refcount[page] = 0
        self._register_key(parent, key, page)
        self._cached[page] = True
        self.tier_stats["promotions"] += 1
        self.tier_stats["cached_peak"] = max(
            self.tier_stats["cached_peak"], len(self._cached))
        return page

    def flush_cached(self):
        """Demote every parked page to the store (pairs with
        `PageStore.flush` at clean shutdown)."""
        while self._cached:
            self._evict_one()
