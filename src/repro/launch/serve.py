"""Batched serving driver: continuous batching over the packed (bit-plane)
serve parameters, with a paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
        --requests 16 --max-new 32 --paged

Design (vLLM-style, shrunk to its essentials):
  * fixed `slots` decode batch; a request FIFO feeds free slots
  * admission is metered by the free-page budget (paged mode), not just by
    free slots — a long request waits until the pool can cover its whole
    lifetime, so mid-flight page allocation can never fail
  * prefill runs per admitted request, right-padded to one of a few bucket
    lengths (the jit cache holds <= len(buckets) prefill signatures instead
    of one per prompt length); its KV is scattered into the slot's pages
    (paged) or slab row (contiguous)
  * one fused decode step advances every active slot each tick with a
    per-slot position vector — each slot's RoPE phase, cache-write index and
    validity mask follow its own clock, so mixed-length traffic decodes
    correctly (the old aligned-position decode used max(pos) for everyone)
  * retirement frees the slot's pages back to the pool; slot reuse and page
    churn never re-jit (the decode signature is fixed)
  * packed weights: `pack_for_serve` (binary/ternary bit-planes, int8 codes)

`--contiguous` keeps the old per-slot slab layout as a reference path; both
run the same per-slot-position decode step. See docs/SERVING.md.

`--mesh DATA,MODEL` serves tensor-parallel: qgemm runs under shard_map
(column-parallel qkv/up, row-parallel out/down with a pre-requant int32
psum), packed weights and the paged pool are device-placed by
launch/sharding.py, and the result is token-exact vs. single-device serving
(tests/test_serving_tp.py). Admission and the PageTable stay host-global.

On a pod this wraps the decode_32k/long_500k dry-run cells: same
decode_step, mesh sharding from launch/sharding.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import kv_cache
from repro.launch.kv_cache import NULL_PAGE, PageTable, pages_for
from repro.models import transformer
from repro.models.common import ModelCtx


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def default_buckets(lo: int, hi: int) -> tuple[int, ...]:
    """Powers-of-two prefill buckets in [lo, hi], always ending at hi."""
    out, b = [], max(lo, 1)
    while b < hi:
        out.append(b)
        b *= 2
    return tuple(out) + (hi,)


class Server:
    def __init__(self, cfg, params, *, slots: int = 4, cache_len: int = 256,
                 paged: bool = True, page_size: int = 32,
                 num_pages: int | None = None,
                 buckets: tuple[int, ...] | None = None,
                 ctx: ModelCtx | None = None, mesh=None):
        self.cfg = cfg
        self.sp = transformer.build_specs(cfg)
        self.params = params
        self.ctx = ctx or ModelCtx(mode="serve")
        self.mesh = mesh
        if mesh is not None:
            # tensor-parallel serving: qgemm runs under shard_map on the
            # "model" axis (column/row per layer spec), batch/pages shard
            # over "data". Admission and the PageTable stay host-global.
            from repro.kernels.dispatch import TPSpec
            self.ctx = dataclasses.replace(
                self.ctx, tp=TPSpec(mesh=mesh, axis="model"))
        self.slots = slots
        self.paged = paged
        self.page_size = page_size
        if paged and cache_len % page_size:
            cache_len += page_size - cache_len % page_size
        self.cache_len = cache_len
        # right-padded prefill is only safe for pure full attention: padding
        # KV would pollute recurrent state outright, and a sliding-window
        # ring keeps the last `window` tokens of the PADDED sequence (the
        # ring-full mask then attends the padding). Those archs bucket to
        # the exact prompt length instead.
        self.exact_prefill = any(k != "attn" for k in cfg.block_pattern)
        if buckets is None:
            buckets = default_buckets(page_size if paged else 8, cache_len)
        self.buckets = tuple(sorted(buckets))

        # pool dtype must match what prefill/decode actually store: the
        # compute dtype, unless the int8-requant cache is configured —
        # otherwise every scatter silently rounds the prefill KV
        kv_dtype = None if cfg.kv_cache_dtype == "int8" else self.ctx.dtype
        if paged:
            self.max_pages = cache_len // page_size
            if num_pages is None:
                num_pages = slots * self.max_pages + 1   # +1: scratch page 0
            self.pt = PageTable(num_pages, page_size, slots, self.max_pages)
            self.cache = transformer.init_cache(cfg, slots, cache_len,
                                                paged=(num_pages, page_size),
                                                kv_dtype=kv_dtype)
            self.paged_mask = kv_cache.paged_leaf_mask(cfg, slots, cache_len,
                                                       num_pages, page_size)
        else:
            self.pt = None
            self.cache = transformer.init_cache(cfg, slots, cache_len,
                                                kv_dtype=kv_dtype)
            self.paged_mask = None

        if mesh is not None:
            # place packed weights by the serve sharding rules (column: N
            # over "model"; row: packed-K words over "model" — guarded by
            # pack.shardable_words) and the cache per-data-shard (pool pages
            # / slab slots over "data"); non-dividing axes replicate. The
            # shard_map in qgemm then consumes the shards in place.
            from repro.launch import sharding as shardlib
            self.params = jax.device_put(
                self.params,
                shardlib.param_shardings(mesh, self.params, fsdp=False))
            self.cache = jax.device_put(
                self.cache, shardlib.serve_cache_shardings(mesh, self.cache))

        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.pos_trace: list[np.ndarray] = []   # per-tick active-slot positions

        self.compile_counts = {"prefill": 0, "decode": 0}
        self._prefill = self._counted("prefill", lambda p, t, lp:
            transformer.prefill(p, t, self.sp, self.ctx,
                                cache_len=self.cache_len, last_pos=lp))
        if paged:
            self._decode = self._counted("decode", lambda p, c, t, pos, pg:
                transformer.decode_step(p, c, t, pos, self.sp, self.ctx,
                                        pages=pg))
        else:
            self._decode = self._counted("decode", lambda p, c, t, pos:
                transformer.decode_step(p, c, t, pos, self.sp, self.ctx))

    def _counted(self, key: str, fn):
        """jit(fn) with a trace-time counter: each distinct signature traces
        the wrapper exactly once, so compile_counts[key] == #signatures."""
        def traced(*args):
            self.compile_counts[key] += 1
            return fn(*args)
        return jax.jit(traced)

    # -- request lifecycle -----------------------------------------------------

    def submit(self, req: Request):
        if len(req.prompt) > self.buckets[-1]:
            raise ValueError(f"prompt len {len(req.prompt)} exceeds max bucket "
                             f"{self.buckets[-1]}")
        if self.paged:
            need = pages_for(self._need_tokens(req), self.page_size)
            if need > self.pt.usable_pages:
                # un-admittable head would livelock run(): admission waits
                # for pages the pool can never have
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.pt.usable_pages} usable; raise --num-pages or "
                    f"shrink the request")
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        if self.exact_prefill:
            return n    # exact-length prefill (recurrent / windowed layers)
        return next(b for b in self.buckets if b >= n)

    def _need_tokens(self, req: Request) -> int:
        """KV tokens this request can write over its whole lifetime."""
        return min(len(req.prompt) + max(req.max_new, 1) - 1, self.cache_len)

    def _outstanding_demand(self) -> int:
        """Pages active slots may still claim (their reserved headroom)."""
        return sum(
            pages_for(self._need_tokens(r), self.page_size) - int(self.pt.held[s])
            for s, r in enumerate(self.slot_req) if r is not None)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue[0]
            if self.paged:
                need = pages_for(self._need_tokens(req), self.page_size)
                if self.pt.free_pages - self._outstanding_demand() < need:
                    break   # FIFO: the head waits for pages; no queue jumping
            self.queue.pop(0)
            n = len(req.prompt)
            bucket = self._bucket(n)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :n] = req.prompt
            logits, rc = self._prefill(self.params, jnp.asarray(toks),
                                       jnp.asarray([n - 1], jnp.int32))
            req.out.append(int(jnp.argmax(logits[0, -1])))
            if self.paged:
                ids = self.pt.admit(s, n)
                pad = pages_for(bucket, self.page_size) - len(ids)
                ids = np.concatenate(
                    [ids, np.full(pad, NULL_PAGE, np.int32)]) if pad else ids
                self.cache = kv_cache.scatter_prefill(
                    self.cache, rc, s, paged_mask=self.paged_mask,
                    page_ids=ids, page_size=self.page_size)
            else:
                self.cache = kv_cache.scatter_prefill(self.cache, rc, s)
            self.slot_req[s] = req
            self.slot_pos[s] = n

    def _retire(self):
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if len(req.out) >= req.max_new or self.slot_pos[s] >= self.cache_len - 1:
                req.done = True
                self.completed.append(req)
                if self.paged:
                    self.pt.retire(s)
                self.slot_req[s] = None
                self.slot_pos[s] = 0

    def step(self):
        """One server tick: admit -> fused decode over active slots -> retire.

        The pre-decode retire pass clears requests that are already complete
        at admission (max_new == 1, or a prompt that fills the cache) so they
        never reach the decode step with nowhere left to write.
        """
        self._admit()
        self._retire()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return bool(self.queue)
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].out[-1]
        if self.paged:
            for s in active:   # cover the write at position slot_pos[s]
                self.pt.extend(s, int(self.slot_pos[s]) + 1)
        self.pos_trace.append(self.slot_pos[active].copy())
        pos = jnp.asarray(self.slot_pos)                    # (slots,) per-slot
        if self.paged:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens), pos,
                                              self.pt.device_table())
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens), pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s in active:
            self.slot_req[s].out.append(int(nxt[s]))
            self.slot_pos[s] += 1
        self._retire()
        return bool(any(r is not None for r in self.slot_req) or self.queue)

    def run(self):
        ticks = 0
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
            ticks += 1
        return ticks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"),
                    help="GEMM backend half of each layer's OperatingPoint "
                         "(precisions come from the policy per layer; both "
                         "backends route through kernels.dispatch.qgemm)")
    ap.add_argument("--impl", default="popcount", choices=("popcount", "mxu"),
                    help="binary/ternary GEMM formulation half of the "
                         "OperatingPoint (int8/int4/mixed cells are "
                         "formulation-agnostic)")
    ap.add_argument("--tune", default=None, metavar="TUNE_JSON",
                    help="kernels.dispatch.TuneTable JSON overriding the "
                         "shipped per-cell Tile table (autotuned block "
                         "shapes per operating point)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="tensor-parallel serving: build a ('data','model') "
                         "mesh of this shape and run qgemm under shard_map "
                         "(e.g. --mesh 2,4; needs data*model visible devices "
                         "— on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--paged", dest="paged", action="store_true", default=True,
                     help="paged KV cache (default): block pool + page table")
    grp.add_argument("--contiguous", dest="paged", action="store_false",
                     help="per-slot slab KV cache (reference layout)")
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size; < slots*cache_len/page_size oversubscribes "
                         "and admission throttles on the page budget")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.policy:
        cfg = dataclasses.replace(cfg, policy=args.policy)

    mesh = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.split(","))
        if d * m > len(jax.devices()):
            raise SystemExit(
                f"--mesh {args.mesh} needs {d * m} devices, have "
                f"{len(jax.devices())} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={d * m} on CPU)")
        mesh = jax.make_mesh((d, m), ("data", "model"))
        print(f"mesh: data={d} x model={m} ({d * m} devices); "
              f"qgemm under shard_map, paged pool sharded over data")

    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    train_b = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    serve_b = sum(np.asarray(x).nbytes for x in jax.tree.leaves(sparams))
    print(f"packed weights: {train_b/2**20:.1f} MiB -> {serve_b/2**20:.1f} MiB "
          f"({train_b/serve_b:.1f}x smaller, policy={cfg.policy})")

    tune = None
    if args.tune:
        from repro.kernels.dispatch import TuneTable
        tune = TuneTable.load(args.tune)
        print(f"tune table: {args.tune} ({len(tune.tiles)} cells, "
              f"source: {tune.source})")

    srv = Server(cfg, sparams, slots=args.slots, cache_len=args.cache_len,
                 paged=args.paged, page_size=args.page_size,
                 num_pages=args.num_pages, mesh=mesh,
                 ctx=ModelCtx(mode="serve", backend=args.backend,
                              impl=args.impl, tune=tune))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=(rng.integers(4, 17),)).astype(np.int32)
        srv.submit(Request(i, prompt, args.max_new))
    ticks = srv.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in srv.completed)
    layout = "paged" if args.paged else "contiguous"
    print(f"served {len(srv.completed)} requests, {total_new} tokens, "
          f"{ticks} ticks, {dt:.1f}s ({total_new/dt:.1f} tok/s on CPU, "
          f"{layout} cache)")
    print(f"jit signatures: prefill={srv.compile_counts['prefill']} "
          f"(buckets={list(srv.buckets)}), decode={srv.compile_counts['decode']}")
    if args.paged:
        print(f"page pool: {srv.pt.usable_pages} usable pages x "
              f"{srv.pt.page_size} tokens, {srv.pt.free_pages} free at exit")
    return srv


if __name__ == "__main__":
    main()
