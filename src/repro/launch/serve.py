"""Batched serving driver: continuous batching over the packed (bit-plane)
serve parameters, with a paged KV cache, prefix sharing, and a
preemption + swap scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
        --requests 16 --max-new 32 --paged --prefix-share --preempt

Design (vLLM-style, shrunk to its essentials):
  * fixed `slots` decode batch; a request FIFO feeds free slots
  * admission is metered by the free-page budget (paged mode), not just by
    free slots. Default (conservative) policy: a request waits until the pool
    can cover its whole lifetime plus running requests' reserved headroom, so
    mid-flight page allocation can never fail. With `--preempt`, admission
    only needs the *prompt's* pages — when the pool runs dry mid-decode, the
    lowest-priority running request is preempted: its pages are swapped to a
    host-side numpy slab and freed, and it resumes later (swap-in to fresh
    pages), token-exactly
  * `--prefix-share`: full (and final-partial) prompt pages are keyed by a
    rolling content hash (kv_cache.prefix_keys); admission maps share-index
    hits instead of allocating, so identical prompt prefixes occupy one set
    of physical pages. A shared page is copy-on-write: the scheduler forks it
    (fresh page + device byte copy) before a slot's decode write would land
    inside it
  * prefill runs per admitted request, right-padded to one of a few bucket
    lengths (the jit cache holds <= len(buckets) prefill signatures instead
    of one per prompt length); its KV is scattered into the slot's pages
    (paged; shared pages are skipped — they already hold this prefix) or
    slab row (contiguous)
  * `--chunk-tokens C` folds prefill INTO the decode tick: an admitted
    request holds all its prompt pages up front but sits in a PREFILLING
    state while one C-token chunk of its prompt runs per tick next to the
    fused decode step (token budget per tick = active decode slots + C), so
    a long prompt no longer freezes every in-flight decode slot. Chunked
    prefill writes byte-identical KV to the whole-prompt path (the chunk
    attention mirrors the blockless prefill algebra exactly —
    models/attention.attn_prefill_chunk) and samples the identical first
    token from the final chunk's logits, so every token-exactness oracle
    holds with chunking on. One fixed chunk signature replaces the prefill
    buckets in the jit budget. Falls back to whole-prompt prefill for archs
    that can't represent a partial prefix in pages (recurrent/window state:
    `exact_prefill`) and for the int8 KV cache (chunk-boundary requant is
    not byte-identical)
  * `--spec-draft planes:P --spec-k K` self-speculative decoding: a DRAFT
    pass over the SAME packed weights — int4/int8 layers contract to their
    P leading bit-planes (kernels.dispatch plane-composed cells) — proposes
    K-1 tokens per tick; one full-precision multi-token VERIFY step (the
    chunk-attention algebra) checks them and the longest exactly-matching
    prefix plus one corrected token land at once. Acceptance is exact token
    match, so serving stays token-exact vs the sequential oracle
  * one fused decode step advances every active slot each tick with a
    per-slot position vector — each slot's RoPE phase, cache-write index and
    validity mask follow its own clock, so mixed-length traffic decodes
    correctly (the old aligned-position decode used max(pos) for everyone)
  * dispatch-ahead double buffering (`dispatch_ahead`, default on): while
    step N's decode/chunk execute on device, the host already runs step
    N+1's scheduling (admission, retire prediction, CoW forks, page
    extends, the masked page table and chunk operands) and stores it as a
    *prepared plan*. Correctness fence: every scheduler mutation bumps an
    epoch counter; a plan is consumed only if its snapshot epoch still
    matches (EOS/retire at fix-up, a new submit, or any fork/swap after the
    plan was built fences it, and the tick rebuilds synchronously —
    stats["fences"] vs stats["plan_hits"])
  * EOS retirement: a request with `eos` set retires the step that token is
    sampled — the slot's pages free immediately and later steps neither
    sample nor write KV for it (data/tokenizer.ByteTokenizer supplies real
    EOS ids)
  * retirement frees the slot's pages back to the pool (refcounted: shared
    pages survive for their co-owners); slot reuse, page churn, CoW forks and
    swaps never re-jit (decode, chunk and fork signatures are fixed)
  * packed weights: `pack_for_serve` (binary/ternary bit-planes, int8 codes)

Request lifecycle states: WAITING (queued) -> [PREFILLING (chunked prompt
in flight) ->] RUNNING (slot + pages) -> PREEMPTED (host swap slab, no
pages) -> RUNNING -> done. A PREFILLING slot is never a preemption victim
(no slot is simultaneously PREFILLING and PREEMPTED — partial-chunk swap
images don't exist). Priority is `(priority desc, rid asc)` — FCFS within a
priority class; the scheduler never preempts a victim at-or-above the
claimant's priority, so the oldest running request always finishes (no
livelock).

Sampling: each request carries (temperature, seed); tokens are drawn
host-side by `models.common.sample_token`, a *stateless* rng keyed by
(seed, token index) — replay is deterministic regardless of batching,
preemption, or sharing history, which is what lets the scheduler tests
demand token-exactness. temperature=0 (default) is greedy argmax.

`--contiguous` keeps the old per-slot slab layout as a reference path; both
run the same per-slot-position decode step. See docs/SERVING.md.

`--mesh DATA,MODEL` serves tensor-parallel: qgemm runs under shard_map
(column-parallel qkv/up, row-parallel out/down with a pre-requant int32
psum), packed weights and the paged pool are device-placed by
launch/sharding.py, and the result is token-exact vs. single-device serving
(tests/test_serving_tp.py, tests/test_serving_sched.py). Admission, the
PageTable (refcounts, hash index) and swap slabs stay host-side. When
`slots` does not divide the data axis, the device batch is padded with
inert phys slots (NULL page rows, position 0, token 0) so every lowered
signature divides the axis — the CPU SPMD partitioner miscompiled
non-dividing batches silently (wrong tokens at slots=3/data=2; regression
in tests/test_serving_tp.py).

On a pod this wraps the decode_32k/long_500k dry-run cells: same
decode_step, mesh sharding from launch/sharding.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import kv_cache
from repro.launch.kv_cache import NULL_PAGE, PageTable, pages_for
from repro.models import transformer
from repro.models.common import ModelCtx, sample_token

WAITING, PREFILLING, RUNNING, PREEMPTED = (
    "WAITING", "PREFILLING", "RUNNING", "PREEMPTED")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    temperature: float = 0.0   # 0 => greedy argmax
    seed: int = 0              # stateless sampling stream (with token index)
    priority: int = 0          # larger = more important; FCFS within a class
    eos: int | None = None     # stop token: retire the step it is sampled
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    state: str = WAITING


@dataclasses.dataclass
class _SwapState:
    """Host-side image of a preempted request: its decode position and the
    numpy slab holding its page bytes + per-slot slab rows."""
    pos: int
    data: object


@dataclasses.dataclass
class _Plan:
    """One prepared device tick: which slots decode, the masked page table
    they see, and (chunked mode) the prefill chunk riding along. Built by
    `_build_plan` — either synchronously at the top of a tick, or ahead of
    time while the previous tick is still executing (dispatch-ahead).
    `epoch` snapshots the scheduler-mutation counter at build completion; a
    plan is only consumable while the snapshot still matches (the fence).
    Token values and the position vector are NOT stored: they are filled at
    dispatch from req.out[-1]/slot_pos, which the fence guarantees are the
    values the plan was built for."""
    epoch: int
    active: list                    # decode slot ids (state RUNNING)
    reqs: list                      # Request per active slot (fix-up targets)
    table: np.ndarray | None        # masked (phys_slots, max_pages), paged only
    chunk: dict | None              # chunk operands, see _plan_chunk
    will_retire: tuple = ()         # predicted retires excluded from `active`


def default_buckets(lo: int, hi: int) -> tuple[int, ...]:
    """Powers-of-two prefill buckets in [lo, hi], always ending at hi."""
    out, b = [], max(lo, 1)
    while b < hi:
        out.append(b)
        b *= 2
    return tuple(out) + (hi,)


class Server:
    def __init__(self, cfg, params, *, slots: int = 4, cache_len: int = 256,
                 paged: bool = True, page_size: int = 32,
                 num_pages: int | None = None,
                 buckets: tuple[int, ...] | None = None,
                 prefix_share: bool = False, preempt: bool = False,
                 chunk_tokens: int = 0, dispatch_ahead: bool = True,
                 spec_draft: str | None = None, spec_k: int = 4,
                 moe_ep: bool = True,
                 ctx: ModelCtx | None = None, mesh=None,
                 page_table=None, model_id: str | None = None,
                 tier=None, tier_watermark: int = 0):
        self.cfg = cfg
        self.sp = transformer.build_specs(cfg)
        self.params = params
        self.ctx = ctx or ModelCtx(mode="serve")
        self.mesh = mesh
        data_dim = 1
        if mesh is not None:
            # tensor-parallel serving: qgemm runs under shard_map on the
            # "model" axis (column/row per layer spec), batch/pages shard
            # over "data". Admission and the PageTable stay host-global.
            from repro.kernels.dispatch import TPSpec
            self.ctx = dataclasses.replace(
                self.ctx, tp=TPSpec(mesh=mesh, axis="model"))
            data_dim = int(mesh.shape["data"])
            if cfg.n_experts and moe_ep:
                # expert-parallel MoE: expert stacks are E-sharded over
                # "model" (the serve param layout already places them there)
                # and the grouped dispatch runs each shard's local experts
                # only — see kernels/dispatch.py EP section. ep_plan falls
                # back per layer when E % model_dim != 0, matching the
                # sharding rules' fit_spec drop.
                from repro.kernels.dispatch import EPSpec
                self.ctx = dataclasses.replace(
                    self.ctx, ep=EPSpec(mesh=mesh, axis="model"))
        if cfg.n_experts:
            # routing telemetry: the serve entry points return a third
            # {"expert_tokens", "dropped"} value; _pop_moe queues it and
            # _drain_moe folds it into Server.stats AFTER the tick's fix-up
            # sync (converting at dispatch would sync the stream and kill
            # the dispatch-ahead overlap)
            self.ctx = dataclasses.replace(self.ctx, moe_stats=True)
        self.slots = slots
        # the CPU SPMD partitioner silently miscompiles batched serve steps
        # whose slot dim does not divide the data axis (wrong tokens, not an
        # error — seed-reproducible at slots=3/data=2). Pad the device batch
        # to the next multiple with inert phys slots: NULL page rows,
        # position 0, token 0 — their writes land on scratch page 0 and the
        # scheduler never looks at them. Host-side scheduling stays at
        # `slots`; only device shapes use `phys_slots`.
        self.phys_slots = -(-slots // data_dim) * data_dim
        self.paged = paged
        self.page_size = page_size
        self.prefix_share = bool(prefix_share)
        self.preempt = bool(preempt)
        if (self.prefix_share or self.preempt) and not paged:
            raise ValueError("--prefix-share/--preempt need the paged cache "
                             "(--contiguous keeps the conservative slab path)")
        if chunk_tokens and not paged:
            raise ValueError("--chunk-tokens needs the paged cache (a partial "
                             "prefix is only representable through pages)")
        if paged and cache_len % page_size:
            cache_len += page_size - cache_len % page_size
        self.cache_len = cache_len
        # right-padded prefill is only safe for pure full attention: padding
        # KV would pollute recurrent state outright, and a sliding-window
        # ring keeps the last `window` tokens of the PADDED sequence (the
        # ring-full mask then attends the padding). Those archs bucket to
        # the exact prompt length instead.
        self.exact_prefill = any(k != "attn" for k in cfg.block_pattern)
        # chunked prefill needs (a) a paged partial prefix — so no recurrent
        # /window state — and (b) pool dtype == compute dtype, or the chunk
        # boundary requant breaks KV byte-identity vs whole-prompt prefill.
        # Fall back to whole-prompt bucketed prefill otherwise.
        self.chunk_tokens = int(chunk_tokens or 0)
        if self.chunk_tokens and (self.exact_prefill
                                  or cfg.kv_cache_dtype == "int8"):
            self.chunk_tokens = 0
        self.dispatch_ahead = bool(dispatch_ahead)
        # self-speculative decoding: a truncated-bit-plane DRAFT pass over
        # the SAME packed weights and pages proposes spec_k-1 tokens per
        # tick; one full-precision multi-token VERIFY step (the chunk
        # attention algebra) checks them, and the accepted prefix plus the
        # first corrected token land at once. Token-exact vs sequential
        # decoding — acceptance is exact token match against what the
        # full-precision pass samples, never a distribution test.
        self.spec = bool(spec_draft)
        self.spec_k = int(spec_k)
        self.spec_planes = 1
        if self.spec:
            kind, _, depth = spec_draft.partition(":")
            if kind != "planes":
                raise ValueError(f"unknown --spec-draft kind {kind!r} "
                                 "(only 'planes[:DEPTH]' exists)")
            self.spec_planes = int(depth) if depth else 1
            if self.spec_k < 1:
                raise ValueError("--spec-k must be >= 1")
            if not paged:
                raise ValueError("--spec-draft needs the paged cache (the "
                                 "verify step replays a multi-token range "
                                 "through pages)")
            if self.chunk_tokens:
                raise ValueError("--spec-draft and --chunk-tokens are "
                                 "mutually exclusive")
            if self.exact_prefill or cfg.kv_cache_dtype == "int8":
                # verify rides the chunk attention path: recurrent/window
                # state can't replay a token range, and the int8 KV requant
                # is not byte-identical at chunk boundaries — fall back to
                # plain sequential decoding rather than lose exactness
                self.spec = False
            else:
                self.dispatch_ahead = False   # spec ticks schedule in line
        if self.spec:
            # layers packed in a direct int4/int8 layout need the plane twin
            # for the draft pass to read (policies with no such layers fall
            # back to a full-precision draft via operating_point's impl
            # fallback — trivially exact, accept-rate 1)
            leaves = {getattr(p[-1], "key", None) for p, _ in
                      jax.tree_util.tree_leaves_with_path(params)}
            if {"w_q", "w_q4"} & leaves and "w_planes" not in leaves:
                raise ValueError(
                    "--spec-draft needs the bit-plane weight twin; pack "
                    "with transformer.pack_for_serve(..., plane_twins=True)")
        if buckets is None:
            buckets = default_buckets(page_size if paged else 8, cache_len)
        self.buckets = tuple(sorted(buckets))

        # pool dtype must match what prefill/decode actually store: the
        # compute dtype, unless the int8-requant cache is configured —
        # otherwise every scatter silently rounds the prefill KV
        kv_dtype = None if cfg.kv_cache_dtype == "int8" else self.ctx.dtype
        # multi-tenant namespace: mixed into every prefix key (hash root +
        # verbatim bytes) so co-tenant models can never alias a page, and
        # the tag under which this server's tier demoter registers
        self.model_id = model_id
        self.ns = model_id.encode() if model_id else b""
        # full-coverage prefill skip (tiered / shared re-admission): when
        # every prompt page arrives from the share index, the first-token
        # logits come from a single 1-token chunk step over the resident KV
        # instead of a full re-prefill. Same algebra constraints as chunked
        # prefill: no recurrent/window state, no int8 KV requant.
        self._skip_prefill_ok = (paged and not self.exact_prefill
                                 and cfg.kv_cache_dtype != "int8")
        if paged:
            self.max_pages = cache_len // page_size
            if page_table is not None:
                # multi-tenant: a SlotView window onto the shared pool
                if page_table.slots != self.phys_slots:
                    raise ValueError(
                        f"page_table view has {page_table.slots} slots, "
                        f"server needs {self.phys_slots}")
                self.pt = page_table
                num_pages = page_table.num_pages
            else:
                if num_pages is None:
                    num_pages = slots * self.max_pages + 1  # +1: scratch page 0
                if tier is not None:
                    from repro.launch.cache_tiers import TieredPageTable
                    self.pt = TieredPageTable(
                        num_pages, page_size, self.phys_slots, self.max_pages,
                        store=tier, watermark=tier_watermark)
                    self.pt._current_ns = self.ns
                else:
                    self.pt = PageTable(num_pages, page_size, self.phys_slots,
                                        self.max_pages)
            self.cache = transformer.init_cache(cfg, self.phys_slots, cache_len,
                                                paged=(num_pages, page_size),
                                                kv_dtype=kv_dtype)
            self.paged_mask = kv_cache.paged_leaf_mask(
                cfg, self.phys_slots, cache_len, num_pages, page_size)
            if hasattr(self.pt, "register_demoter"):
                self.pt.register_demoter(
                    self.ns,
                    lambda pid: kv_cache.gather_pages(self.cache, [pid],
                                                      self.paged_mask))
        else:
            if page_table is not None or tier is not None:
                raise ValueError("page_table/tier need the paged cache")
            self.pt = None
            self.cache = transformer.init_cache(cfg, self.phys_slots, cache_len,
                                                kv_dtype=kv_dtype)
            self.paged_mask = None

        if mesh is not None:
            # place packed weights by the serve sharding rules (column: N
            # over "model"; row: packed-K words over "model" — guarded by
            # pack.shardable_words) and the cache per-data-shard (pool pages
            # / slab slots over "data"); non-dividing axes replicate. The
            # shard_map in qgemm then consumes the shards in place.
            from repro.launch import sharding as shardlib
            self.params = jax.device_put(
                self.params,
                shardlib.param_shardings(mesh, self.params, fsdp=False))
            self.cache = jax.device_put(
                self.cache, shardlib.serve_cache_shardings(mesh, self.cache))

        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(self.phys_slots, np.int32)
        self.queue: list[Request] = []
        self.preempted: list[Request] = []
        self._swap: dict[int, _SwapState] = {}
        self._prefill_ctx: dict[int, dict] = {}   # slot -> chunked-prefill state
        self.completed: list[Request] = []
        self.pos_trace: list[np.ndarray] = []   # per-tick active-slot positions
        self.stats = {"shared_pages": 0, "cow_forks": 0,
                      "preemptions": 0, "resumes": 0, "peak_pages": 0,
                      "chunk_ticks": 0, "plan_hits": 0, "fences": 0,
                      "spec_ticks": 0, "spec_proposed": 0,
                      "spec_accepted": 0, "spec_emitted": 0,
                      "admitted": 0, "prefill_skips": 0,
                      "tier_hits_device": 0, "tier_hits_host": 0,
                      "tier_hits_disk": 0}
        if cfg.n_experts:
            # moe_routed = total top-k assignments (kept + dropped);
            # moe_expert_tokens[e] = assignments expert e actually served
            self.stats.update({"moe_routed": 0, "moe_dropped": 0,
                               "moe_expert_tokens": [0] * cfg.n_experts})
        self._moe_pending: list = []
        # multi-tenant hooks (set by launch/multi_serve.MultiServer):
        # extern_demand() -> pages co-tenant running slots may still claim
        # (joins this server's conservative admission reservation);
        # reclaim_hook(worse_than) -> True if it preempted one strictly-
        # lower-priority co-tenant slot (extends _make_room across tenants)
        self.extern_demand = None
        self.reclaim_hook = None
        # dispatch-ahead state: the prepared next tick and the mutation epoch
        # that fences it (every scheduler mutation — admit, retire, preempt,
        # resume, fork, submit — bumps the epoch; a plan built at epoch e is
        # dead the moment the epoch moves past e)
        self._epoch = 0
        self._prepared: _Plan | None = None

        self.compile_counts = {"prefill": 0, "decode": 0, "cow": 0,
                               "chunk": 0, "draft": 0, "verify": 0}
        self._signatures: dict[str, set] = {k: set()
                                            for k in self.compile_counts}
        self._prefill = self._counted("prefill", lambda p, t, lp:
            transformer.prefill(p, t, self.sp, self.ctx,
                                cache_len=self.cache_len, last_pos=lp))
        if paged:
            self._decode = self._counted("decode", lambda p, c, t, pos, pg:
                transformer.decode_step(p, c, t, pos, self.sp, self.ctx,
                                        pages=pg))
            # CoW byte copy: scalar (src, dst) page ids -> fixed signature,
            # so fork traffic compiles exactly once
            self._cow = self._counted("cow", lambda c, a, b:
                kv_cache.copy_page(c, a, b, self.paged_mask))
            self._chunk = self._counted("chunk", lambda p, c, t, p0, rp, wp, nr, li:
                transformer.prefill_chunk(p, c, t, p0, self.sp, self.ctx,
                                          read_pages=rp, write_pages=wp,
                                          nreal=nr, last_idx=li))
            if self.spec:
                # draft context: layers that resolve to a plane-composed
                # cell contract to the leading spec_planes MSB planes (the
                # sign plane alone at depth 1); everything else — and every
                # policy pair without a plane cell — runs full precision,
                # so the draft degrades toward exact instead of breaking
                draft_ctx = dataclasses.replace(self.ctx, impl="planes",
                                                draft_planes=self.spec_planes)
                self._draft = self._counted("draft", lambda p, c, t, pos, pg:
                    transformer.decode_step(p, c, t, pos, self.sp, draft_ctx,
                                            pages=pg))
                self._verify = self._counted("verify",
                    lambda p, c, t, p0, rp, wp, nr:
                        transformer.decode_verify(p, c, t, p0, self.sp,
                                                  self.ctx, read_pages=rp,
                                                  write_pages=wp, nreal=nr))
        else:
            self._decode = self._counted("decode", lambda p, c, t, pos:
                transformer.decode_step(p, c, t, pos, self.sp, self.ctx))

    @staticmethod
    def _abstract_sig(args):
        """Abstract signature of a traced call: treedef + per-leaf
        (shape, dtype, weak_type) — exactly what decides whether jax.jit
        re-traces, minus sharding/donation (which the server holds fixed)."""
        leaves, treedef = jax.tree.flatten(args)
        def leaf_sig(l):
            a = getattr(l, "aval", None)
            if a is not None:
                return (tuple(a.shape), str(a.dtype),
                        bool(getattr(a, "weak_type", False)))
            return ("static", repr(l))
        return (treedef, tuple(leaf_sig(l) for l in leaves))

    def _counted(self, key: str, fn):
        """jit(fn) with signature-set accounting: compile_counts[key] is the
        number of DISTINCT abstract signatures ever traced under `key` — not
        a call-site trace tally. A re-trace of a signature already seen
        (jit-cache eviction, jax.clear_caches) does not inflate the count,
        and a new signature slipping through a reused key always raises it —
        what the --jit-budget gate actually wants to bound."""
        def traced(*args):
            self._signatures[key].add(self._abstract_sig(args))
            self.compile_counts[key] = len(self._signatures[key])
            return fn(*args)
        return jax.jit(traced)

    # -- request lifecycle -----------------------------------------------------

    def _pop_moe(self, res, count: bool = True):
        """Strip the trailing MoE-stats leaf from a jitted serve-step result
        (the ctx.moe_stats 3-tuple contract) and queue the device arrays for
        the deferred drain. `count=False` drops the stats instead (the spec
        DRAFT pass re-routes the same positions the verify step counts —
        counting both would double-book). No-op when stats are off."""
        if not self.ctx.moe_stats:
            return res
        *rest, st = res
        if count and st is not None:
            self._moe_pending.append(st)
        return tuple(rest)

    def _drain_moe(self):
        """Fold queued per-call routing counters into Server.stats. Called at
        the END of a tick, after fix-up already synced the device stream —
        np.asarray here is free, while converting at dispatch would serialize
        dispatch-ahead."""
        for st in self._moe_pending:
            et = np.asarray(st["expert_tokens"])
            dropped = int(np.asarray(st["dropped"]))
            self.stats["moe_dropped"] += dropped
            self.stats["moe_routed"] += int(et.sum()) + dropped
            self.stats["moe_expert_tokens"] = [
                a + int(b)
                for a, b in zip(self.stats["moe_expert_tokens"], et)]
        self._moe_pending.clear()

    def submit(self, req: Request):
        if len(req.prompt) > self.buckets[-1]:
            raise ValueError(f"prompt len {len(req.prompt)} exceeds max bucket "
                             f"{self.buckets[-1]}")
        if self.paged:
            # lifetime pages alone decide servability: a request that ends up
            # running solo can never need a CoW fork (refcount > 1 requires a
            # live co-owner slot), so no +1 for sharing here — the per-tick
            # fork debt is reserved by admission, not by submit
            need = pages_for(self._need_tokens(req), self.page_size)
            if need > self.pt.usable_pages:
                # un-admittable head would livelock run(): admission (and,
                # under --preempt, a solo run after evicting everyone) waits
                # for pages the pool can never have
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.pt.usable_pages} usable; raise --num-pages or "
                    f"shrink the request")
        req.state = WAITING
        self.queue.append(req)
        self._epoch += 1   # fence: a prepared plan didn't see this arrival

    def _bucket(self, n: int) -> int:
        if self.exact_prefill:
            return n    # exact-length prefill (recurrent / windowed layers)
        return next(b for b in self.buckets if b >= n)

    def _need_tokens(self, req: Request) -> int:
        """KV tokens this request can write over its whole lifetime."""
        return min(len(req.prompt) + max(req.max_new, 1) - 1, self.cache_len)

    @staticmethod
    def _prio(req: Request):
        """Scheduler key: smaller sorts first = more important. Larger
        `priority` wins; FCFS (rid) breaks ties. Victims are chosen from the
        max end, so the oldest highest-priority request is never preempted."""
        return (-req.priority, req.rid)

    def _sample(self, req: Request, logits_row) -> int:
        return sample_token(logits_row, req.temperature, req.seed,
                            len(req.out))

    # -- admission -------------------------------------------------------------

    def _outstanding_demand(self) -> int:
        """Pages active slots may still claim (their reserved headroom)."""
        return sum(
            pages_for(self._need_tokens(r), self.page_size) - int(self.pt.held[s])
            for s, r in enumerate(self.slot_req) if r is not None)

    def _fork_debt(self, extra_shared=frozenset(),
                   extra_writer_pages=()) -> int:
        """Pages CoW forks may still claim, counted exactly per PHYSICAL
        page: a page with effective refcount r and w slots whose next decode
        write lands inside it can absorb at most min(w, r - 1) forks — each
        fork drops the refcount by one, and the last co-owner standing
        writes in place, no copy. The old per-slot tally (one page per slot
        with a pending CoW, plus one for the candidate's own shared
        boundary page) overcounted aliased writers and double-counted a
        page against both the candidate and the slot it shares with — e.g.
        an in-flight PREFILLING slot whose deferred index registration is
        about to cover that very page — rejecting admissible work under
        --prefix-share + --chunk-tokens.

        `extra_shared`: pages a candidate admission would map (effective
        refcount +1 each). `extra_writer_pages`: pages the candidate itself
        will write into on its first decode (its boundary page when that
        arrives shared). For a PREFILLING slot the next decode write is at
        position n (its chunk clock slot_pos is still inside the prompt)."""
        writers: dict[int, int] = {}
        for s, r in enumerate(self.slot_req):
            if r is None:
                continue
            pos = (self._prefill_ctx[s]["n"] if r.state == PREFILLING
                   else int(self.slot_pos[s]))
            idx = pos // self.page_size
            if idx >= int(self.pt.held[s]):
                continue              # next write opens a fresh page
            pid = int(self.pt.table[s, idx])
            writers[pid] = writers.get(pid, 0) + 1
        for pid in extra_writer_pages:
            writers[pid] = writers.get(pid, 0) + 1
        debt = 0
        for pid, w in writers.items():
            rc = int(self.pt.refcount[pid]) + (1 if pid in extra_shared else 0)
            if rc > 1:
                debt += min(w, rc - 1)
        return debt

    def _admission_ok(self, req: Request, keys) -> bool:
        """Page-budget admission test for the queue head.

        --preempt: only the prompt's pages (minus share hits) must be free —
        decode headroom is reclaimed later by preempting, so the conservative
        reservation no longer rejects admissible work (PageTable.can_admit's
        `reclaimable` is the same accounting, used on the resume path).
        Default: lifetime reservation — free pages must cover this request's
        whole lifetime plus every running request's remaining headroom and
        pending CoW-fork debt, so extend/fork can never fail mid-flight.
        """
        hits = self.pt.lookup_keys(keys) if keys is not None else []
        nhit = sum(1 for p in hits if p is not None)
        # effective supply: a tiered table's parked pages count as free, but
        # parked pages this probe HITS will be mapped, not reclaimed — they
        # must not fund the miss allocations (free_pages_for nets them out)
        free = (self.pt.free_pages_for(keys)
                if hasattr(self.pt, "free_pages_for") else self.pt.free_pages)
        if self.preempt:
            need_now = pages_for(len(req.prompt), self.page_size) - nhit
            return free >= need_now
        lifetime = pages_for(self._need_tokens(req), self.page_size) - nhit
        extern = self.extern_demand() if self.extern_demand is not None else 0
        debt = 0
        if self.prefix_share:
            # the candidate's own first decode write lands in its final
            # prompt page; when that page arrives shared (a boundary hit) it
            # is one more writer in the same per-page accounting — not an
            # unconditional +1 on top (that double-counted it against the
            # slot it shares with)
            boundary = (hits[-1],) if (hits and hits[-1] is not None
                                       and len(req.prompt) % self.page_size
                                       ) else ()
            debt = self._fork_debt({p for p in hits if p is not None},
                                   boundary)
        return free - self._outstanding_demand() - debt - extern >= lifetime

    def _tier_promote(self, keys):
        """Re-materialize host/disk-tier slabs for this prompt's leading
        missing prefix pages, so the admission that follows maps them as
        share hits (and, on full coverage, skips prefill outright).

        Prefix-closed walk: accumulate the verbatim chain over consumed
        keys; at the first share-index miss, probe the store with the
        restart-stable content key `(covered, hash, chain)`. A store hit is
        adopted — allocated, registered under the live `(parent, key)`
        chain, parked at refcount 0 — and its bytes scattered into this
        server's pool before anything can map it. The walk stops at the
        first store miss (deeper pages are unreachable without it) and
        never evicts to fund itself (promotion only spends REAL free pages
        — cannibalizing the device tier to fill the device tier is churn).
        """
        store = getattr(self.pt, "store", None)
        if store is None or not keys:
            return
        hits = self.pt.lookup_keys(keys)
        parent, chain = kv_cache._ROOT, b""
        for key, hit in zip(keys, hits):
            if hit is not None:
                parent, chain = hit, chain + key[2]
                continue
            chain = chain + key[2]
            if not getattr(self.pt, "_free", ()):
                break              # no real free page to land the slab on
            image, tiername = store.get((key[0], key[1], chain))
            if image is None:
                break
            page = self.pt.adopt(parent, key, chain, self.ns)
            self.cache = kv_cache.scatter_pages(self.cache, image, [page],
                                                self.paged_mask)
            if self.mesh is not None:
                from repro.launch import sharding as shardlib
                self.cache = shardlib.repin_serve_cache(self.mesh, self.cache)
            self.stats["tier_hits_host" if tiername == "host"
                       else "tier_hits_disk"] += 1
            parent = page

    def _count_device_hits(self, keys):
        """Per-tenant device-tier accounting: share hits about to re-admit
        a PARKED page are device-tier hits for this server (the table's own
        counter is pool-global)."""
        if keys is None or not hasattr(self.pt, "is_cached"):
            return
        self.stats["tier_hits_device"] += sum(
            1 for p in self.pt.lookup_keys(keys)
            if p is not None and self.pt.is_cached(p))

    def _skip_prefill(self, s: int, req: Request, n: int):
        """First-token logits for a fully-resident prompt (every page came
        from the share index) via ONE 1-token chunk step at position n-1 —
        no re-prefill. The write table is all-NULL: the resident pages are
        shared/parked and must not be rewritten (their bytes are already
        byte-identical to what this prompt's prefill would produce); the
        chunk's in-flight K/V for its own row feeds the attention directly,
        so the logits match the full prefill's final row bit-for-bit
        (jit-vs-jit, same algebra as the chunked-prefill final chunk)."""
        read = self.pt.table[s].copy()
        write = np.full_like(read, NULL_PAGE)
        toks = np.asarray([[req.prompt[-1]]], np.int32)
        c_logits, self.cache = self._pop_moe(self._chunk(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray([n - 1], jnp.int32), jnp.asarray(read)[None],
            jnp.asarray(write)[None], jnp.asarray([1], jnp.int32),
            jnp.asarray([0], jnp.int32)))
        req.out.append(self._sample(req, np.asarray(c_logits)[0, 0]))
        self.stats["prefill_skips"] += 1

    def _try_start(self, s: int) -> bool:
        """Prefill + admit the queue head into slot s (False: it must wait)."""
        req = self.queue[0]
        keys = None
        if self.paged:
            keys = (kv_cache.prefix_keys(req.prompt, self.page_size,
                                         namespace=self.ns)
                    if self.prefix_share else None)
            if keys is not None:
                self._tier_promote(keys)
            if not self._admission_ok(req, keys):
                return False   # FIFO: the head waits for pages; no jumping
        self.queue.pop(0)
        n = len(req.prompt)
        if self.paged and keys is not None:
            self._count_device_hits(keys)
            ids, shared = self.pt.admit_shared(s, n, keys)
            self.stats["shared_pages"] += int(shared.sum())
            if shared.all() and self._skip_prefill_ok:
                # the whole prompt is already resident — first token from
                # one chunk step over the shared pages, no prefill at all
                self._skip_prefill(s, req, n)
                self._finish_start(s, req, n)
                return True
            # shared pages already hold this prefix's KV (and possibly a
            # co-owner's decode bytes past it) — never rescatter them
            scatter_ids = np.where(shared, NULL_PAGE, ids).astype(np.int32)
        elif self.paged:
            scatter_ids = self.pt.admit(s, n)
        bucket = self._bucket(n)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt
        logits, rc = self._pop_moe(self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray([n - 1], jnp.int32)))
        req.out.append(self._sample(req, np.asarray(logits[0, -1])))
        if self.paged:
            pad = pages_for(bucket, self.page_size) - len(scatter_ids)
            if pad:
                scatter_ids = np.concatenate(
                    [scatter_ids, np.full(pad, NULL_PAGE, np.int32)])
            self.cache = kv_cache.scatter_prefill(
                self.cache, rc, s, paged_mask=self.paged_mask,
                page_ids=scatter_ids, page_size=self.page_size)
        else:
            self.cache = kv_cache.scatter_prefill(self.cache, rc, s)
        self._finish_start(s, req, n)
        return True

    def _finish_start(self, s: int, req: Request, n: int):
        req.state = RUNNING
        self.slot_req[s] = req
        self.slot_pos[s] = n
        self.stats["admitted"] += 1
        self._epoch += 1

    def _defer_for_inflight(self, keys) -> bool:
        """True if the queue head must wait one tick: its first prefix page
        misses the share index but an in-flight PREFILLING slot is building
        exactly that page (same first key). Admitting now would allocate a
        private copy of a prefix about to become shareable — deferring keeps
        chunked prefix sharing as effective as the whole-prompt path, where
        admission and indexing were atomic."""
        if not keys:
            return False
        if self.pt.lookup_keys(list(keys[:1]))[0] is not None:
            return False
        for s, r in enumerate(self.slot_req):
            if r is not None and r.state == PREFILLING:
                okeys = self._prefill_ctx[s]["keys"]
                if okeys and okeys[0] == keys[0]:
                    return True
        return False

    def _start_chunked(self, s: int) -> bool:
        """Admit the queue head into slot s in PREFILLING state (chunked
        prefill). All prompt pages are claimed up front — the page-budget
        accounting is identical to `_try_start` — but no prefill runs here:
        step() feeds one --chunk-tokens chunk per tick through the fused
        chunk step. Leading shared pages already hold this prefix's KV, so
        the chunk clock starts past them (always leaving >= 1 token: the
        final chunk must produce the first-token logits). Share-index
        registration of the slot's own pages is deferred until chunks
        actually cover them (PageTable.index_pages at each chunk landing)."""
        req = self.queue[0]
        keys = (kv_cache.prefix_keys(req.prompt, self.page_size,
                                     namespace=self.ns)
                if self.prefix_share else None)
        if keys is not None:
            self._tier_promote(keys)
        if not self._admission_ok(req, keys):
            return False   # FIFO: the head waits for pages; no jumping
        if self._defer_for_inflight(keys):
            return False
        self.queue.pop(0)
        n = len(req.prompt)
        shared = None
        lead = 0
        if keys is not None:
            self._count_device_hits(keys)
            ids, shared = self.pt.admit_shared(s, n, keys, defer_index=True)
            self.stats["shared_pages"] += int(shared.sum())
            if shared.all() and self._skip_prefill_ok:
                # fully resident (tier re-admission): no chunks to run at
                # all — sample the first token and go straight to RUNNING
                self._skip_prefill(s, req, n)
                self._finish_start(s, req, n)
                return True
            while lead < len(shared) and shared[lead]:
                lead += 1
        else:
            self.pt.admit(s, n)
        self._prefill_ctx[s] = {"keys": keys, "shared": shared, "n": n}
        req.state = PREFILLING
        self.slot_req[s] = req
        self.slot_pos[s] = min(lead * self.page_size, n - 1)  # chunk clock
        self.stats["admitted"] += 1
        self._epoch += 1
        return True

    def _admit(self):
        """Fill free slots: resume preempted requests first (strict priority
        — fresh work never jumps a swapped-out request), then the FIFO head."""
        for s in range(self.slots):
            if self.slot_req[s] is not None:
                continue
            if self.preempted:
                if not self._resume_into(s):
                    break
                continue
            if not self.queue:
                break
            started = (self._start_chunked(s) if self.chunk_tokens
                       else self._try_start(s))
            if not started:
                break

    # -- preemption / swap -----------------------------------------------------

    def _preempt(self, s: int):
        """Swap slot s out: gather its page bytes + slab rows to a host numpy
        slab, release its pages (refcounted — shared pages survive for their
        co-owners), and park the request on the preempted list."""
        req = self.slot_req[s]
        # gather exactly the pages the resume will scatter back: those
        # covering the decode position. Speculative ticks extend coverage
        # past pos (the verify step writes lookahead rows); those pages hold
        # rejected-draft garbage and must not enter the swap image —
        # swap_in_slot scatters pages_for(pos) pages, a larger slab would
        # shape-mismatch. swap_out below still releases EVERY held page.
        ids = self.pt.slot_pages(s)[: pages_for(int(self.slot_pos[s]),
                                                self.page_size)]
        data = kv_cache.swap_out_slot(self.cache, s, ids, self.paged_mask)
        self.pt.swap_out(s)
        self._swap[req.rid] = _SwapState(int(self.slot_pos[s]), data)
        req.state = PREEMPTED
        self.preempted.append(req)
        self.slot_req[s] = None
        self.slot_pos[s] = 0
        self.stats["preemptions"] += 1
        self._epoch += 1

    def _make_room(self, need_free: int, worse_than) -> bool:
        """Preempt strictly-lower-priority running requests (worst first)
        until `need_free` pages are free. False if victims run out."""
        while self.pt.free_pages < need_free:
            # only RUNNING slots are eligible victims: a PREFILLING slot has
            # no well-defined swap image (its pages are mid-chunk) and no
            # saved decode position to resume from
            victims = [s for s, r in enumerate(self.slot_req)
                       if r is not None and r.state == RUNNING
                       and self._prio(r) > worse_than]
            if not victims:
                # multi-tenant: ask the coordinator to preempt a strictly-
                # lower-priority co-tenant slot (frees pages in the SHARED
                # pool); victims shrink every call, so this terminates
                if (self.reclaim_hook is not None
                        and self.reclaim_hook(worse_than)):
                    continue
                return False
            self._preempt(max(victims,
                              key=lambda v: self._prio(self.slot_req[v])))
        return True

    def _resume_into(self, s: int) -> bool:
        """Swap the most-important preempted request back into slot s."""
        req = min(self.preempted, key=self._prio)
        st = self._swap[req.rid]
        # cover through the NEXT write (pos + 1), not just the saved
        # coverage: resuming into exactly pages_for(pos) free pages would
        # swap the whole KV in only for _prepare_pages to find the pool dry
        # at its extend and swap it straight back out — a full round trip
        # with zero decode progress (swapped-in pages are private, so no
        # CoW page is ever needed on top). swap_in CLAIMS that coverage
        # immediately — a later resume or admission in this same pass cannot
        # consume the write page out from under an earlier, more important
        # resume (a pre-check alone would not be held across the pass).
        cover = min(st.pos + 1, self.max_pages * self.page_size)
        need = pages_for(cover, self.page_size)
        if self.pt.free_pages < need:
            reclaim = sum(int(self.pt.held[v])
                          for v, r in enumerate(self.slot_req)
                          if r is not None and r.state == RUNNING
                          and self._prio(r) > self._prio(req))
            if not self.pt.can_admit(cover, reclaimable=reclaim):
                return False
            # can_admit's reclaimable may overcount shared pages; verify by
            # actually evicting, and give up until next tick if it falls short
            if not self._make_room(need, self._prio(req)):
                return False
        ids = self.pt.swap_in(s, cover)
        # the saved slab covers pages_for(pos) pages; a boundary resume
        # allocates one page beyond it, filled by the very next decode write
        self.cache = kv_cache.swap_in_slot(
            self.cache, st.data, s, ids[: pages_for(st.pos, self.page_size)],
            self.paged_mask)
        if self.mesh is not None:
            from repro.launch import sharding as shardlib
            self.cache = shardlib.repin_serve_cache(self.mesh, self.cache)
        self.preempted.remove(req)
        del self._swap[req.rid]
        req.state = RUNNING
        self.slot_req[s] = req
        self.slot_pos[s] = st.pos
        self.stats["resumes"] += 1
        self._epoch += 1
        return True

    # -- serving loop ----------------------------------------------------------

    def _retire(self, skip=frozenset(), quiet=frozenset()):
        """Clear completed slots: out of budget, cache full, or EOS sampled.

        `skip`: slots with a token still in flight (dispatch-ahead build) —
        their out list is one short of the truth, so they must not be judged
        here (the will_retire prediction covers them). `quiet`: slots whose
        retirement the prepared plan already predicted — retiring them does
        NOT bump the epoch, so the prediction keeps the plan consumable.
        PREFILLING slots never retire here: slot_pos is their chunk clock,
        not a decode position (an n == cache_len prompt would falsely trip
        the cache-full test mid-prefill)."""
        for s, req in enumerate(self.slot_req):
            if req is None or s in skip or req.state == PREFILLING:
                continue
            eos = False
            if req.eos is not None and req.eos in req.out:
                # a multi-token accept can land tokens PAST the stop token
                # in one tick; generation ends at EOS, so truncate there and
                # retire now. (The old `out[-1] == eos` test only caught a
                # final-position EOS and kept decoding past a mid-batch one.)
                del req.out[req.out.index(req.eos) + 1:]
                eos = True
            if (len(req.out) >= req.max_new or eos
                    or self.slot_pos[s] >= self.cache_len - 1):
                req.done = True
                self.completed.append(req)
                if self.paged:
                    self.pt.retire(s)
                self.slot_req[s] = None
                self.slot_pos[s] = 0
                self._prefill_ctx.pop(s, None)
                if s not in quiet:
                    self._epoch += 1

    def _prepare_pages(self, skip=frozenset(), lookahead=None):
        """Per-tick page work, most-important slot first: CoW-fork every
        shared page the tick will write into, then extend coverage through
        the write range. When the pool runs dry (--preempt only; the
        conservative reservation makes it unreachable otherwise), evict
        strictly-lower-priority victims — or the claimant itself when none
        remain. PREFILLING slots need no work (all prompt pages were claimed
        at admission; chunks never CoW — shared pages are write-masked);
        `skip` holds predicted-retire slots, which will never write again.

        `lookahead` (speculative ticks): slot -> token positions the tick
        writes, [pos, pos+la). The draft chain and the verify step both
        scribble across the whole range before the accept decision, so every
        shared held page in it must fork NOW — a shared page left in place
        would take rejected-draft bytes a co-owner could read. Default la=1
        is exactly the sequential single-write behavior."""
        order = sorted((s for s, r in enumerate(self.slot_req)
                        if r is not None and r.state == RUNNING
                        and s not in skip),
                       key=lambda v: self._prio(self.slot_req[v]))
        for s in order:
            req = self.slot_req[s]
            if req is None:
                continue           # preempted by a more important slot's claim
            pos = int(self.slot_pos[s])
            la = 1 if lookahead is None else int(lookahead.get(s, 1))
            last_pg = (pos + la - 1) // self.page_size
            need = max(0, (last_pg + 1) - int(self.pt.held[s]))
            forkable = []
            if self.prefix_share:
                for idx in range(pos // self.page_size,
                                 min(last_pg + 1, int(self.pt.held[s]))):
                    tokpos = max(pos, idx * self.page_size)
                    if self.pt.cow_pending(s, tokpos):
                        forkable.append(tokpos)
                need += len(forkable)
            if need > self.pt.free_pages:
                if not self.preempt or not self._make_room(need, self._prio(req)):
                    if self.preempt:
                        self._preempt(s)   # no cheaper victim: swap itself out
                        continue
                    raise RuntimeError(
                        "page pool exhausted mid-decode without --preempt "
                        "(admission reservation should have prevented this)")
            for tokpos in forkable:
                fork = self.pt.fork_cow(s, tokpos)
                if fork is not None:
                    src, dst = fork
                    self.cache = self._cow(self.cache, jnp.int32(src),
                                           jnp.int32(dst))
                    self.stats["cow_forks"] += 1
                    self._epoch += 1   # table remap: fences any stale plan
            self.pt.extend(s, pos + la)

    def _plan_chunk(self) -> dict | None:
        """Operands for this tick's prefill chunk: the most-important
        PREFILLING slot advances by min(chunk_tokens, remaining prompt).
        `read` is the slot's real page row (attention must see shared-prefix
        KV); `write` NULLs the shared pages so the chunk can never scribble
        on a co-owner's bytes (its own tokens inside a fully-shared page are
        already there, byte-identically, from whoever built the page)."""
        cands = [s for s, r in enumerate(self.slot_req)
                 if r is not None and r.state == PREFILLING]
        if not cands:
            return None
        s = min(cands, key=lambda v: self._prio(self.slot_req[v]))
        req = self.slot_req[s]
        pctx = self._prefill_ctx[s]
        n, C = pctx["n"], self.chunk_tokens
        covered = int(self.slot_pos[s])
        creal = min(C, n - covered)
        toks = np.zeros((1, C), np.int32)
        toks[0, :creal] = req.prompt[covered:covered + creal]
        final = covered + creal >= n
        read = self.pt.table[s].copy()
        write = read.copy()
        if pctx["shared"] is not None:
            sh = np.asarray(pctx["shared"], bool)
            write[:len(sh)][sh] = NULL_PAGE
        return {"slot": s, "tokens": toks, "pos0": covered, "nreal": creal,
                "final": final, "last_idx": creal - 1 if final else 0,
                "read": read, "write": write}

    def _build_plan(self, pending=frozenset()) -> _Plan:
        """One tick's scheduling: admit/resume -> retire -> predict retires
        of in-flight slots -> page work (CoW fork, extend, preempt) -> the
        masked device table and chunk operands. `pending` holds slots whose
        token is still on device (dispatch-ahead): they are skipped by the
        real retire pass (their out list is one short) and instead retired
        *predictively* — excluded from the next actives, retired quietly at
        fix-up. EOS cannot be predicted; it retires loudly and fences.

        The epoch is snapshotted at the END: every mutation this build
        itself makes (admissions, forks, preemptions...) is part of the
        plan, not a reason to fence it."""
        self._admit()
        self._retire(skip=pending)
        will_retire = []
        for s in pending:
            req = self.slot_req[s]
            if req is None or req.state != RUNNING:
                continue
            if (len(req.out) + 1 >= req.max_new
                    or self.slot_pos[s] >= self.cache_len - 1):
                will_retire.append(s)
        skip = frozenset(will_retire)
        if self.paged:
            self._prepare_pages(skip=skip)
            # physical pool pressure (aliasing-aware: shared pages count
            # once) — what the slab layout would need is Σ per-slot coverage
            self.stats["peak_pages"] = max(
                self.stats["peak_pages"],
                self.pt.usable_pages - self.pt.free_pages)
        active = [s for s, r in enumerate(self.slot_req)
                  if r is not None and r.state == RUNNING and s not in skip]
        reqs = [self.slot_req[s] for s in active]
        table = None
        if self.paged:
            # mask non-decoding rows to NULL: a PREFILLING slot's pages must
            # not take the decode write at its chunk-clock position, and the
            # inert phys-slot padding rows never had pages. NULL rows write
            # scratch page 0 and read nothing valid (pos 0, token 0).
            table = self.pt.table.copy()
            rowmask = np.ones(len(table), bool)
            rowmask[active] = False
            table[rowmask] = NULL_PAGE
        chunk = self._plan_chunk() if self.chunk_tokens else None
        return _Plan(epoch=self._epoch, active=active, reqs=reqs,
                     table=table, chunk=chunk,
                     will_retire=tuple(will_retire))

    def _spec_step(self):
        """One self-speculative tick: DRAFT up to spec_k-1 tokens per slot
        with the truncated-plane context, VERIFY them in one full-precision
        multi-token step, accept the longest exactly-matching prefix plus
        the first corrected token.

        Token-exactness: every ACCEPTED token is sampled (same stateless
        (seed, index) rng) from verify logits computed over exactly the
        inputs the sequential path would have fed — row t of the verify
        chunk consumes [last_token, draft_0..draft_{t-1}], and the accept
        loop only reaches row t when all those drafts matched the verify
        samples (transformer.decode_verify). The draft decides HOW MANY
        rows are usable, never WHAT tokens land.

        The draft chain threads a throwaway cache lineage: reduced-precision
        draft KV feeds later draft steps but never survives — verify starts
        from the pre-draft cache and rewrites the whole [pos, pos+k) range
        with exact KV, so rejected-draft bytes cannot leak into any future
        read. Positions past the accepted point hold garbage from rejected
        inputs; they are overwrite-before-read safe (the next tick scatters
        from the rewound position before its gather, and its causal mask
        never reaches past its own rows)."""
        self._admit()
        self._retire()
        # per-slot window: never past the request budget or the final cache
        # slot (the _retire above guarantees >= 1 for every RUNNING slot)
        keff = {}
        for s, r in enumerate(self.slot_req):
            if r is not None and r.state == RUNNING:
                keff[s] = max(1, min(self.spec_k, r.max_new - len(r.out),
                                     self.cache_len - 1 - int(self.slot_pos[s])))
        self._prepare_pages(lookahead=keff)
        active = [s for s in sorted(keff) if self.slot_req[s] is not None
                  and self.slot_req[s].state == RUNNING]
        self.stats["peak_pages"] = max(
            self.stats["peak_pages"],
            self.pt.usable_pages - self.pt.free_pages)
        if not active:
            return bool(self.queue or self.preempted
                        or any(r is not None for r in self.slot_req))
        reqs = {s: self.slot_req[s] for s in active}
        base = {s: int(self.slot_pos[s]) for s in active}
        self.pos_trace.append(self.slot_pos[active].copy())
        table = self.pt.table.copy()
        rowmask = np.ones(len(table), bool)
        rowmask[active] = False
        table[rowmask] = NULL_PAGE
        # -- draft: sequential truncated-plane decode steps, batched over
        # the slots still inside their window (finished rows mask to NULL)
        drafts = {s: [] for s in active}
        cur = {s: reqs[s].out[-1] for s in active}
        dcache = self.cache
        for j in range(self.spec_k - 1):
            live = [s for s in active if j < keff[s] - 1]
            if not live:
                break
            tokens = np.zeros((self.phys_slots, 1), np.int32)
            pos = np.zeros(self.phys_slots, np.int32)
            dtab = table.copy()
            dmask = np.ones(len(dtab), bool)
            dmask[live] = False
            dtab[dmask] = NULL_PAGE
            for s in live:
                tokens[s, 0] = cur[s]
                pos[s] = base[s] + j
            dlogits, dcache = self._pop_moe(
                self._draft(self.params, dcache, jnp.asarray(tokens),
                            jnp.asarray(pos), jnp.asarray(dtab)),
                count=False)   # verify re-routes these positions exactly
            rows = np.asarray(dlogits[:, 0])
            for s in live:
                r = reqs[s]
                d = sample_token(rows[s], r.temperature, r.seed,
                                 len(r.out) + j)
                drafts[s].append(d)
                cur[s] = d
        # -- verify: one chunk-algebra step over [last_token, drafts...] per
        # slot, writing exact KV across the whole window (read and write
        # tables coincide: the lookahead fork above made every page in the
        # write range exclusively owned)
        tokens = np.zeros((self.phys_slots, self.spec_k), np.int32)
        pos0 = np.zeros(self.phys_slots, np.int32)
        nreal = np.zeros(self.phys_slots, np.int32)
        for s in active:
            row = [reqs[s].out[-1]] + drafts[s]
            tokens[s, :len(row)] = row
            pos0[s] = base[s]
            nreal[s] = keff[s]
        vlogits, self.cache = self._pop_moe(self._verify(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos0),
            jnp.asarray(table), jnp.asarray(table), jnp.asarray(nreal)))
        vrows = np.asarray(vlogits)
        self.stats["spec_ticks"] += 1
        for s in active:
            r = reqs[s]
            emitted = []
            n_acc = 0
            for t in range(keff[s]):
                v = sample_token(vrows[s, t], r.temperature, r.seed,
                                 len(r.out) + t)
                emitted.append(v)
                if t < len(drafts[s]):
                    if drafts[s][t] != v:
                        break
                    n_acc += 1
            self.stats["spec_proposed"] += len(drafts[s])
            self.stats["spec_accepted"] += n_acc
            self.stats["spec_emitted"] += len(emitted)
            r.out.extend(emitted)
            # exact-KV coverage = inputs consumed by the accepted rows; the
            # last emitted token was never fed, so the next tick feeds it at
            # exactly this position (garbage beyond is overwritten there)
            self.slot_pos[s] = base[s] + len(emitted)
        self._epoch += 1
        self._retire()   # truncates at a mid-batch EOS before retiring
        self._drain_moe()
        return bool(any(r is not None for r in self.slot_req) or self.queue
                    or self.preempted)

    def step(self):
        """One server tick: consume the prepared plan (or build one) ->
        dispatch the fused decode and the prefill chunk -> optimistically
        advance host state and build the NEXT plan while the device works ->
        fix-up (sample the landed tokens, retire).

        Dispatch-ahead fence: the prepared plan is consumed iff its epoch
        snapshot still matches — nothing (submit, EOS/unpredicted retire,
        preemption, resume, fork) mutated the scheduler after it was built.
        A mismatch trips stats["fences"] and rebuilds synchronously; a match
        is stats["plan_hits"].

        The pre-decode retire pass inside _build_plan clears requests that
        are already complete at admission (max_new == 1, or a prompt that
        fills the cache) so they never reach the decode step with nowhere
        left to write."""
        if self.spec:
            return self._spec_step()
        plan = None
        if self._prepared is not None:
            if self._prepared.epoch == self._epoch:
                plan = self._prepared
                self.stats["plan_hits"] += 1
            else:
                self.stats["fences"] += 1
            self._prepared = None
        if plan is None:
            plan = self._build_plan()
        active, chunk = plan.active, plan.chunk
        if not active and chunk is None:
            return bool(self.queue or self.preempted
                        or any(r is not None for r in self.slot_req))
        # -- dispatch: decode first, then the chunk. Functional cache
        # chaining orders the device ops; the two touch disjoint pages (or
        # read-only-shared ones — decode write pages are pre-forked), so
        # either order is token-exact; decode-first matches the sequential
        # oracle's schedule.
        logits = greedy = nxt_dev = None
        if active:
            tokens = np.zeros((self.phys_slots, 1), np.int32)
            pos = np.zeros(self.phys_slots, np.int32)
            for i, s in enumerate(active):
                tokens[s, 0] = plan.reqs[i].out[-1]
                pos[s] = self.slot_pos[s]
            self.pos_trace.append(self.slot_pos[active].copy())
            if self.paged:
                logits, self.cache = self._pop_moe(self._decode(
                    self.params, self.cache, jnp.asarray(tokens),
                    jnp.asarray(pos), jnp.asarray(plan.table)))
            else:
                logits, self.cache = self._pop_moe(self._decode(
                    self.params, self.cache, jnp.asarray(tokens),
                    jnp.asarray(pos)))
            greedy = not any(r.temperature > 0 for r in plan.reqs)
            if greedy:
                # argmax on device, transfer (slots,) ints — not the whole
                # vocab matrix (np and jnp argmax both break ties to the
                # lowest index, so this equals sample_token at temp 0)
                nxt_dev = jnp.argmax(logits[:, 0], axis=-1)
        c_logits = None
        chunk_req = None
        if chunk is not None:
            cs = chunk["slot"]
            chunk_req = self.slot_req[cs]
            self.stats["chunk_ticks"] += 1
            c_logits, self.cache = self._pop_moe(self._chunk(
                self.params, self.cache, jnp.asarray(chunk["tokens"]),
                jnp.asarray([chunk["pos0"]], jnp.int32),
                jnp.asarray(chunk["read"])[None],
                jnp.asarray(chunk["write"])[None],
                jnp.asarray([chunk["nreal"]], jnp.int32),
                jnp.asarray([chunk["last_idx"]], jnp.int32)))
        # -- optimistic host advance (deterministic consequences of the
        # dispatch — token VALUES stay unknown until fix-up)
        for s in active:
            self.slot_pos[s] += 1
        if chunk is not None:
            cs = chunk["slot"]
            self.slot_pos[cs] = chunk["pos0"] + chunk["nreal"]
            pctx = self._prefill_ctx[cs]
            if self.prefix_share and pctx["keys"] is not None:
                # progressive share-index registration: pages whose keyed
                # coverage the chunks now reach become mappable by later
                # admissions (deferred from admit_shared)
                self.pt.index_pages(cs, pctx["keys"],
                                    int(self.slot_pos[cs]))
            if chunk["final"]:
                chunk_req.state = RUNNING
                self._prefill_ctx.pop(cs, None)
        # -- dispatch-ahead: overlap next tick's host scheduling with this
        # tick's device work (the jitted calls above returned futures)
        if self.dispatch_ahead:
            pend = set(active)
            if chunk is not None and chunk["final"]:
                pend.add(chunk["slot"])
            self._prepared = self._build_plan(pending=frozenset(pend))
        # -- fix-up: the device tokens land in the Request objects CAPTURED
        # at dispatch (plan.reqs) — a pending slot may have been preempted
        # (or its slot re-assigned) during the ahead build
        if active:
            if greedy:
                nxt = np.asarray(nxt_dev)
                for i, s in enumerate(active):
                    self._deliver(plan.reqs[i], int(nxt[s]))
            else:
                rows = np.asarray(logits[:, 0])        # (slots, V) to host
                for i, s in enumerate(active):
                    r = plan.reqs[i]
                    self._deliver(r, self._sample(r, rows[s]))
        if chunk is not None and chunk["final"]:
            self._deliver(chunk_req,
                          self._sample(chunk_req, np.asarray(c_logits)[0, 0]))
        quiet = (frozenset(self._prepared.will_retire)
                 if self._prepared is not None else frozenset())
        self._retire(quiet=quiet)
        self._drain_moe()
        return bool(any(r is not None for r in self.slot_req) or self.queue
                    or self.preempted)

    def _deliver(self, req: Request, tok: int):
        """Append a landed token; finish a request that completed while
        PREEMPTED (its slot was swapped out during the ahead build after its
        last token dispatched — _retire only sees slotted requests, and a
        resumed overrun past EOS would be wrong)."""
        req.out.append(tok)
        if req.state == PREEMPTED and (
                len(req.out) >= req.max_new
                or (req.eos is not None and tok == req.eos)):
            req.done = True
            self.completed.append(req)
            self.preempted.remove(req)
            del self._swap[req.rid]
            self._epoch += 1   # the prepared plan may have planned its resume

    def run(self):
        ticks = 0
        while (self.queue or self.preempted
               or any(r is not None for r in self.slot_req)):
            self.step()
            ticks += 1
        return ticks

    def flush_tier(self):
        """Demote every parked device-tier page to the store and push the
        store's host tier to disk — the clean-shutdown path that makes
        indexed prefixes survive a restart (tests/CI kill-and-restart
        smoke). No-op without a tiered table."""
        if hasattr(self.pt, "flush_cached"):
            self.pt.flush_cached()
            store = getattr(self.pt, "store", None)
            if store is not None:
                store.flush()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"),
                    help="GEMM backend half of each layer's OperatingPoint "
                         "(precisions come from the policy per layer; both "
                         "backends route through kernels.dispatch.qgemm)")
    ap.add_argument("--impl", default="popcount",
                    choices=("popcount", "mxu", "planes"),
                    help="GEMM formulation half of the OperatingPoint: "
                         "popcount/mxu pick the binary/ternary cell "
                         "(int8/int4/mixed cells are formulation-agnostic); "
                         "'planes' routes int4/int8-weight layers through "
                         "the bit-plane-composed cells (per-layer fallback "
                         "to popcount where no plane cell exists)")
    ap.add_argument("--paged-attn", default="auto",
                    choices=("auto", "gather", "fused"),
                    help="paged decode-attention read path: 'auto' runs the "
                         "fused Pallas page-walk kernel "
                         "(kernels.paged_attn) iff --backend pallas, "
                         "'fused'/'gather' force it on/off (gather = the "
                         "jnp oracle path)")
    ap.add_argument("--tune", default=None, metavar="TUNE_JSON",
                    help="kernels.dispatch.TuneTable JSON overriding the "
                         "shipped per-cell Tile table (autotuned block "
                         "shapes per operating point)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="tensor-parallel serving: build a ('data','model') "
                         "mesh of this shape and run qgemm under shard_map "
                         "(e.g. --mesh 2,4; needs data*model visible devices "
                         "— on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--paged", dest="paged", action="store_true", default=True,
                     help="paged KV cache (default): block pool + page table")
    grp.add_argument("--contiguous", dest="paged", action="store_false",
                     help="per-slot slab KV cache (reference layout; keeps "
                          "the conservative slot/lifetime admission)")
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size; < slots*cache_len/page_size oversubscribes "
                         "and admission throttles on the page budget")
    ap.add_argument("--prefix-share", action="store_true",
                    help="hash-index full prompt pages so identical prefixes "
                         "map one set of physical pages (copy-on-write on "
                         "decode divergence)")
    ap.add_argument("--preempt", action="store_true",
                    help="admit on prompt pages only; when the pool runs dry "
                         "mid-decode, swap the lowest-priority running "
                         "request to a host slab and resume it later")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="fold prefill into the decode tick: one chunk of "
                         "this many prompt tokens runs per tick next to the "
                         "fused decode (0 = whole-prompt bucketed prefill). "
                         "Token-exact and KV byte-identical vs whole-prompt; "
                         "needs --paged")
    ap.add_argument("--spec-draft", default=None, metavar="KIND[:DEPTH]",
                    help="self-speculative decoding: draft next tokens with "
                         "a truncated formulation over the SAME packed "
                         "weights ('planes:1' = sign-plane-only draft), "
                         "verify with one full-precision multi-token step "
                         "per tick; token-exact vs sequential decoding. "
                         "Needs --paged; exclusive with --chunk-tokens")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculative window: draft K-1 tokens and verify "
                         "K rows per tick (with --spec-draft)")
    ap.add_argument("--moe-ep", dest="moe_ep", action="store_true",
                    default=True,
                    help="expert-parallel MoE serving (default, MoE archs "
                         "under --mesh): shard expert stacks over the "
                         "'model' axis and run the grouped expert dispatch "
                         "(each shard computes only its local experts); "
                         "token-exact vs the dense expert vmap")
    ap.add_argument("--no-moe-ep", dest="moe_ep", action="store_false",
                    help="keep the replicated dense expert vmap under "
                         "--mesh (oracle / fallback path)")
    ap.add_argument("--no-dispatch-ahead", dest="dispatch_ahead",
                    action="store_false", default=True,
                    help="disable double buffering (host prepares tick N+1 "
                         "while tick N runs on device; an epoch fence "
                         "rebuilds when a submit/EOS/preemption invalidates "
                         "the prepared plan)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token id: a request retires the step this "
                         "token is sampled (pages free immediately; later "
                         "steps neither sample nor write KV for it)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy); "
                         "stateless rng keyed by (seed, token index)")
    ap.add_argument("--jit-budget", type=int, default=None,
                    help="fail (exit 1) if the total trace-time compile "
                         "signatures (prefill buckets + decode + cow + "
                         "chunk) exceed this — the CI recompile-regression "
                         "gate")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.policy:
        cfg = dataclasses.replace(cfg, policy=args.policy)

    mesh = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.split(","))
        if d * m > len(jax.devices()):
            raise SystemExit(
                f"--mesh {args.mesh} needs {d * m} devices, have "
                f"{len(jax.devices())} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={d * m} on CPU)")
        mesh = jax.make_mesh((d, m), ("data", "model"))
        print(f"mesh: data={d} x model={m} ({d * m} devices); "
              f"qgemm under shard_map, paged pool sharded over data")

    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(
        params, cfg,
        plane_twins=args.spec_draft is not None or args.impl == "planes")
    train_b = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    serve_b = sum(np.asarray(x).nbytes for x in jax.tree.leaves(sparams))
    print(f"packed weights: {train_b/2**20:.1f} MiB -> {serve_b/2**20:.1f} MiB "
          f"({train_b/serve_b:.1f}x smaller, policy={cfg.policy})")

    tune = None
    if args.tune:
        from repro.kernels.dispatch import TuneTable
        tune = TuneTable.load(args.tune)
        print(f"tune table: {args.tune} ({len(tune.tiles)} cells, "
              f"source: {tune.source})")

    srv = Server(cfg, sparams, slots=args.slots, cache_len=args.cache_len,
                 paged=args.paged, page_size=args.page_size,
                 num_pages=args.num_pages, mesh=mesh,
                 prefix_share=args.prefix_share, preempt=args.preempt,
                 chunk_tokens=args.chunk_tokens,
                 dispatch_ahead=args.dispatch_ahead,
                 spec_draft=args.spec_draft, spec_k=args.spec_k,
                 moe_ep=args.moe_ep,
                 ctx=ModelCtx(mode="serve", backend=args.backend,
                              impl=args.impl, tune=tune,
                              paged_attn=args.paged_attn))
    if args.chunk_tokens and not srv.chunk_tokens:
        print("chunked prefill disabled: arch needs exact-length prefill "
              "or int8 KV (fell back to whole-prompt buckets)")
    if args.spec_draft and not srv.spec:
        print("speculative decoding disabled: arch needs exact-length "
              "prefill or int8 KV (verify rides the chunk path); "
              "fell back to sequential decode")
    if args.paged:
        fused = (args.paged_attn == "fused"
                 or (args.paged_attn == "auto" and args.backend == "pallas"))
        print(f"decode attention: {'fused pallas page-walk kernel' if fused else 'jnp gather path'} "
              f"(--paged-attn {args.paged_attn}, --backend {args.backend})")
    rng = np.random.default_rng(0)
    # with --prefix-share, every request repeats a common prompt prefix
    # (page-aligned so it aliases whole pages) and request 1 duplicates
    # request 0 EXACTLY — the duplicate aliases the partial boundary page
    # too, so the co-running pair forces a CoW fork on its first divergent
    # decode write (exact-coverage keys mean prefix-only overlap never
    # shares the boundary page, hence never forks)
    shared_prefix = (rng.integers(0, cfg.vocab,
                                  size=(args.page_size,)).astype(np.int32)
                     if args.prefix_share else None)
    t0 = time.time()
    first = None
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=(rng.integers(4, 17),)).astype(np.int32)
        if shared_prefix is not None:
            prompt = np.concatenate([shared_prefix, prompt[:8]])
            if i == 0:
                first = prompt
            elif i == 1:
                prompt = first.copy()
        srv.submit(Request(i, prompt, args.max_new,
                           temperature=args.temperature, seed=i,
                           eos=args.eos_id))
    ticks = srv.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in srv.completed)
    layout = "paged" if args.paged else "contiguous"
    print(f"served {len(srv.completed)} requests, {total_new} tokens, "
          f"{ticks} ticks, {dt:.1f}s ({total_new/dt:.1f} tok/s on CPU, "
          f"{layout} cache)")
    total_sigs = sum(srv.compile_counts.values())
    print(f"jit signatures: prefill={srv.compile_counts['prefill']} "
          f"(buckets={list(srv.buckets)}), decode={srv.compile_counts['decode']}, "
          f"cow={srv.compile_counts['cow']}, "
          f"chunk={srv.compile_counts['chunk']}, "
          f"draft={srv.compile_counts['draft']}, "
          f"verify={srv.compile_counts['verify']}, total={total_sigs}")
    if srv.chunk_tokens:
        print(f"chunked prefill: {srv.stats['chunk_ticks']} chunk ticks "
              f"(--chunk-tokens {srv.chunk_tokens})")
    if srv.spec:
        prop = srv.stats["spec_proposed"]
        acc = srv.stats["spec_accepted"]
        sticks = max(srv.stats["spec_ticks"], 1)
        print(f"speculative: {srv.stats['spec_ticks']} spec ticks, "
              f"accept-rate {acc}/{prop} ({acc / max(prop, 1):.0%}), "
              f"{srv.stats['spec_emitted'] / sticks:.2f} tokens/tick "
              f"(--spec-draft {args.spec_draft}, --spec-k {srv.spec_k})")
    if srv.dispatch_ahead:
        print(f"dispatch-ahead: {srv.stats['plan_hits']} plan hits, "
              f"{srv.stats['fences']} fences")
    if args.paged:
        print(f"page pool: {srv.pt.usable_pages} usable pages x "
              f"{srv.pt.page_size} tokens, {srv.pt.free_pages} free at exit")
    if cfg.n_experts:
        routed = max(srv.stats["moe_routed"], 1)
        et = srv.stats["moe_expert_tokens"]
        util = [f"{v / max(sum(et), 1):.2f}" for v in et]
        mode = "EP grouped dispatch" if srv.ctx.ep is not None \
            else "dense expert vmap"
        print(f"moe: {mode}, routed={srv.stats['moe_routed']} "
              f"dropped={srv.stats['moe_dropped']} "
              f"(drop-rate {srv.stats['moe_dropped'] / routed:.1%}), "
              f"expert util {util}")
    if args.prefix_share or args.preempt:
        print(f"scheduler: shared_pages={srv.stats['shared_pages']} "
              f"cow_forks={srv.stats['cow_forks']} "
              f"preemptions={srv.stats['preemptions']} "
              f"resumes={srv.stats['resumes']}")
    if args.jit_budget is not None and total_sigs > args.jit_budget:
        raise SystemExit(f"jit budget exceeded: {total_sigs} trace-time "
                         f"signatures > committed budget {args.jit_budget}")
    return srv


if __name__ == "__main__":
    main()
