"""Batched serving driver: continuous-batching-lite over the packed
(bit-plane) serve parameters.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
        --requests 16 --max-new 32

Design (vLLM-style, shrunk to its essentials):
  * fixed `slots` decode batch; a request queue feeds free slots
  * prefill runs per admitted request (right-sized jit cache), its KV is
    scattered into the slot cache
  * one fused decode step advances every active slot each tick
  * per-slot positions & EOS retirement; slot reuse without re-jitting
  * packed weights: `pack_for_serve` (binary/ternary bit-planes, int8 codes)

On a pod this wraps the decode_32k/long_500k dry-run cells: same
decode_step, mesh sharding from launch/sharding.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry, transformer
from repro.models.common import ModelCtx


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg, params, *, slots: int = 4, cache_len: int = 256,
                 ctx: ModelCtx | None = None):
        self.cfg = cfg
        self.sp = transformer.build_specs(cfg)
        self.params = params
        self.ctx = ctx or ModelCtx(mode="serve")
        self.slots = slots
        self.cache_len = cache_len
        self.cache = transformer.init_cache(cfg, slots, cache_len)
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self.completed: list[Request] = []

        self._decode = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, self.sp, self.ctx))
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(p, t, self.sp, self.ctx,
                                             cache_len=self.cache_len),
            static_argnames=())

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                logits, cache = self._prefill(self.params, req.prompt[None, :])
                tok = int(jnp.argmax(logits[0, -1]))
                req.out.append(tok)
                # scatter this request's prefill cache into slot s
                def put(slot_c, req_c):
                    return slot_c.at[s if slot_c.shape[0] == self.slots else 0].set(
                        req_c[0]) if slot_c.shape[0] == self.slots else slot_c
                self.cache = jax.tree.map(
                    lambda sc, rc: sc.at[s].set(rc[0].astype(sc.dtype)),
                    self.cache, cache)
                self.slot_req[s] = req
                self.slot_pos[s] = len(req.prompt)

    def _retire(self):
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if len(req.out) >= req.max_new or self.slot_pos[s] >= self.cache_len - 1:
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None

    def step(self):
        """One server tick: admit -> fused decode over active slots -> retire."""
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].out[-1]
        # aligned-position decode (per-slot positions kept host-side; the
        # fused step uses the max — inactive slots' writes are harmless)
        pos = int(self.slot_pos[active].max())
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens), jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s in active:
            self.slot_req[s].out.append(int(nxt[s]))
            self.slot_pos[s] += 1
        self._retire()
        return bool(self.slot_req != [None] * self.slots or self.queue)

    def run(self):
        ticks = 0
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
            ticks += 1
        return ticks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"),
                    help="GEMM backend for the packed serve path (both route "
                         "through kernels.dispatch.qgemm)")
    ap.add_argument("--impl", default="popcount", choices=("popcount", "mxu"),
                    help="binary/ternary GEMM formulation")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.policy:
        cfg = dataclasses.replace(cfg, policy=args.policy)

    params = transformer.init(jax.random.PRNGKey(0), cfg)
    sparams = transformer.pack_for_serve(params, cfg)
    train_b = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    serve_b = sum(np.asarray(x).nbytes for x in jax.tree.leaves(sparams))
    print(f"packed weights: {train_b/2**20:.1f} MiB -> {serve_b/2**20:.1f} MiB "
          f"({train_b/serve_b:.1f}x smaller, policy={cfg.policy})")

    srv = Server(cfg, sparams, slots=args.slots,
                 ctx=ModelCtx(mode="serve", backend=args.backend,
                              impl=args.impl))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=(rng.integers(4, 17),)).astype(np.int32)
        srv.submit(Request(i, prompt, args.max_new))
    ticks = srv.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in srv.completed)
    print(f"served {len(srv.completed)} requests, {total_new} tokens, "
          f"{ticks} ticks, {dt:.1f}s ({total_new/dt:.1f} tok/s on CPU)")
    return srv


if __name__ == "__main__":
    main()
