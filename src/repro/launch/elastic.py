"""Failure handling, straggler mitigation, elastic re-meshing.

Synchronous SPMD has exactly three realistic levers at 1000+ nodes, all
implemented here at laptop scale with the same interfaces:

1. StepMonitor — per-step wall-time EWMA; flags spikes (stragglers) and
   returns a policy verdict. On a pod the orchestrator uses these verdicts to
   decide when a slow host should be evicted (-> lever 3).

2. Checkpoint/restart — launch/train.py: atomic checkpoints + --resume; the
   step-indexed data pipeline makes restarts bit-exact. Failure injection
   (--fail-at-step) exercises the full loop (tested in tests/test_train_e2e).

3. Elastic re-mesh — checkpoints are mesh-agnostic (saved unsharded per
   logical leaf with the mesh recorded); `reshard_restore` brings a
   checkpoint up on a *different* device count/mesh, re-applying the sharding
   rules for the new mesh. A 512-chip job that loses a pod restarts on 256
   with the same code path (tested 8 -> 4 fake devices in tests/test_elastic).
"""
from __future__ import annotations

import dataclasses

import jax

from repro.checkpoint import ckpt

from . import sharding


@dataclasses.dataclass
class StepMonitor:
    """EWMA step-time monitor with straggler verdicts."""
    alpha: float = 0.2
    spike_factor: float = 2.0
    ewma: float | None = None
    spikes: int = 0
    history: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> str | None:
        verdict = None
        if self.ewma is not None and dt > self.spike_factor * self.ewma:
            self.spikes += 1
            verdict = f"straggler-spike x{dt / self.ewma:.1f}"
            # policy hook: at >3 consecutive spikes a pod orchestrator would
            # mark this host slow and trigger elastic re-mesh (lever 3)
            if self.spikes >= 3:
                verdict = "straggler-persistent: recommend evict+remesh"
        else:
            self.spikes = 0
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        self.history.append((step, dt))
        return verdict


def reshard_restore(ckpt_dir: str, like_tree, mesh, *, fsdp: bool = True,
                    step: int | None = None):
    """Restore a checkpoint onto a (possibly different) mesh: the sharding
    rules are re-derived for the new mesh and each leaf is device_put with
    its new NamedSharding."""
    shardings = sharding.param_shardings(mesh, like_tree, fsdp=fsdp)
    return ckpt.restore(ckpt_dir, like_tree, step=step, shardings=shardings)
