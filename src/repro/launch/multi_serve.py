"""Multi-tenant, multi-arch serving over ONE shared page pool.

    PYTHONPATH=src python -m repro.launch.multi_serve --reduced \
        --tenant llama3.2-3b,ternary --tenant gemma3-4b,w-ternary \
        --prefix-share --preempt --requests 8 --jit-budget 12

BrainTTA's thesis is one flexible substrate serving heterogeneous networks
instead of N fixed engines; this is the serving-layer analogue. Each tenant
is a registry entry — (arch config, precision policy, operating point) —
with its OWN packed weight set, its own device cache pool, and its own
jitted step functions (prefill/chunk/decode signatures stay per-model, so
the `--jit-budget` discipline holds per entry). What is SHARED is the page
allocator: one `PageTable` (or `TieredPageTable`) whose slot rows are
carved into per-tenant windows (`kv_cache.SlotView`), so every tenant's
pages come out of one physical budget, compete under one preemption/swap
scheduler, and live in one prefix-share index — with prefix keys namespaced
by model id (hash root + verbatim bytes, `kv_cache.prefix_keys`), so two
models can never alias a page even on identical token streams.

Scheduling:
  * **weighted round-robin admission** — each tick rotates which tenant
    steps (and therefore admits) first through a weight-expanded cycle, so
    under page contention a weight-2 tenant gets first claim on free pages
    twice as often as a weight-1 tenant. No tenant is ever skipped in a
    tick; the rotation orders claims, it does not gate them.
  * **priority classes** — a tenant's `priority` becomes the default
    `Request.priority` of its traffic, and `Server`'s existing preemption
    scheduler consumes it; cross-tenant reclaim (`Server.reclaim_hook`)
    lets a starved higher-priority tenant preempt a strictly-lower-priority
    co-tenant's slot, swap image and all. Request ids are globally unique
    so the (priority desc, rid asc) order is coherent across tenants.
  * **conservative co-reservation** — without `--preempt`, each tenant's
    lifetime-reservation admission also subtracts every CO-tenant's
    outstanding page demand (`Server.extern_demand`), preserving the
    "extend can never fail mid-flight" invariant on the shared pool.
  * **per-tenant SLO counters** — submitted/admitted/preempted/dropped plus
    TTFT/ITL percentiles (ticks and wall seconds), surfaced through each
    tenant's `Server.stats` and aggregated by `MultiServer.stats()`.

Token-exactness: every tenant's output is token-exact vs its own
single-model sequential oracle while co-scheduled (tests/test_multi_serve).
The shared table is only an allocator — pages of different tenants never
alias (namespaced keys), a tenant's masked decode table contains only its
own rows, and each tenant's KV bytes live in its own device pool.

Tiering (`--tier-dir`): the shared table becomes a `TieredPageTable`; any
tenant's retired prefixes park on device, demote to host/disk, and are
re-admitted — across tenants' lifetimes and across process restarts —
without re-prefilling (see launch/cache_tiers.py, docs/SERVING.md).

Not supported here: `--mesh` tensor parallelism (single-tenant `serve.py`
keeps it; multi-tenant TP would need per-tenant meshes over one device set)
and `--spec-draft` (per-tenant speculative serving composes, but is out of
scope for the multi-tenant driver).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
import zlib

import numpy as np

from repro.launch.cache_tiers import PageStore, TieredPageTable
from repro.launch.kv_cache import PageTable
from repro.launch.serve import Request, Server
from repro.models import registry


@dataclasses.dataclass
class TenantSpec:
    """One registry entry of the multi-tenant server."""
    model_id: str                  # unique name; becomes the key namespace
    arch: str                      # configs.get_config name
    policy: str | None = None      # precision policy override
    backend: str = "jnp"
    impl: str = "popcount"
    slots: int = 2                 # decode-batch slots in the shared table
    cache_len: int = 64            # per-slot KV budget (tokens)
    weight: int = 1                # weighted-round-robin admission weight
    priority: int = 0              # priority class -> Request.priority default
    max_queue: int | None = None   # admission-queue cap; beyond it: dropped
    chunk_tokens: int = 0          # per-tenant chunked prefill
    reduced: bool = False
    seed: int = 0                  # weight-init seed


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


class MultiServer:
    """N tenant `Server`s scheduled onto one shared `PageTable`.

    Construction: the shared table has `sum(t.slots)` rows and per-slot
    width `max(t.cache_len) // page_size`; each tenant gets a `SlotView`
    window and builds its own device cache pool of `num_pages` pages (pages
    are per-tenant STORAGE but a shared page-id BUDGET — the allocator,
    refcounts, and prefix index are global, which is what creates the
    cross-tenant pressure, fairness, and reuse dynamics).
    """

    def __init__(self, tenants, *, page_size: int = 8,
                 num_pages: int | None = None, prefix_share: bool = False,
                 preempt: bool = False, dispatch_ahead: bool = True,
                 tier: PageStore | None = None, tier_watermark: int = 0,
                 dtype=None):
        if len({t.model_id for t in tenants}) != len(tenants):
            raise ValueError("tenant model_ids must be unique (they namespace "
                             "the shared prefix index)")
        self.tenants = list(tenants)
        total_slots = sum(t.slots for t in self.tenants)
        width = max(-(-t.cache_len // page_size) for t in self.tenants)
        if num_pages is None:
            num_pages = total_slots * width + 1
        if tier is not None:
            self.pt = TieredPageTable(num_pages, page_size, total_slots,
                                      width, store=tier,
                                      watermark=tier_watermark)
        else:
            self.pt = PageTable(num_pages, page_size, total_slots, width)
        self.servers: dict[str, Server] = {}
        base = 0
        for t in self.tenants:
            cfg, packed, ctx = registry.build_serve_entry(
                t.arch, policy=t.policy, reduced=t.reduced,
                backend=t.backend, impl=t.impl, dtype=dtype, seed=t.seed)
            view = self.pt.view(base, t.slots, t.model_id.encode())
            srv = Server(cfg, packed, slots=t.slots, cache_len=t.cache_len,
                         paged=True, page_size=page_size,
                         prefix_share=prefix_share, preempt=preempt,
                         chunk_tokens=t.chunk_tokens,
                         dispatch_ahead=dispatch_ahead, ctx=ctx,
                         page_table=view, model_id=t.model_id)
            if srv.cache_len != t.cache_len:
                raise ValueError(f"tenant {t.model_id}: cache_len "
                                 f"{t.cache_len} not a page multiple")
            self.servers[t.model_id] = srv
            base += t.slots
        for mid, srv in self.servers.items():
            srv.extern_demand = self._extern_demand(mid)
            if preempt:
                srv.reclaim_hook = self._reclaim(mid)
        # weighted round-robin cycle: tenant ids repeated by weight; the
        # pointer advances one entry per tick and the tick's step order is
        # the de-duplicated cycle read from the pointer
        self._cycle = [t.model_id for t in self.tenants
                       for _ in range(max(1, t.weight))]
        self._rr = 0
        self._rid = 0
        self.ticks = 0
        # SLO tracking: per-request submit/first-token/done marks
        self._pending: dict[int, tuple[str, Request]] = {}
        self._marks: dict[int, dict] = {}
        self.slo = {t.model_id: {"submitted": 0, "dropped": 0, "completed": 0,
                                 "ttft_ticks": [], "itl_ticks": [],
                                 "ttft_s": [], "itl_s": []}
                    for t in self.tenants}

    # -- cross-tenant coupling -------------------------------------------------

    def _extern_demand(self, mid: str):
        def demand():
            return sum(o._outstanding_demand() + o._fork_debt()
                       for m, o in self.servers.items() if m != mid)
        return demand

    def _reclaim(self, mid: str):
        """Preempt one RUNNING slot of a co-tenant, strictly worse than
        `worse_than` in the global (priority desc, rid asc) order; worst
        victim first. Returns True iff a slot was preempted (its pages are
        back in the shared pool — possibly fewer than hoped if shared)."""
        def reclaim(worse_than) -> bool:
            best = None
            for m, o in self.servers.items():
                if m == mid:
                    continue
                for s, r in enumerate(o.slot_req):
                    if (r is not None and r.state == "RUNNING"
                            and o._prio(r) > worse_than):
                        if best is None or o._prio(r) > best[2]:
                            best = (o, s, o._prio(r))
            if best is None:
                return False
            best[0]._preempt(best[1])
            return True
        return reclaim

    # -- request intake --------------------------------------------------------

    def submit(self, model_id: str, prompt, max_new: int, *,
               temperature: float = 0.0, seed: int = 0,
               eos: int | None = None, priority: int | None = None) -> int | None:
        """Queue a request for one tenant. Returns the global rid, or None
        when the tenant's queue cap drops it. The tenant's priority class is
        the default request priority (a per-request override still wins)."""
        t = next(t for t in self.tenants if t.model_id == model_id)
        srv = self.servers[model_id]
        rec = self.slo[model_id]
        rec["submitted"] += 1
        if t.max_queue is not None and len(srv.queue) >= t.max_queue:
            rec["dropped"] += 1
            return None
        rid = self._rid
        self._rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new,
                      temperature=temperature, seed=seed, eos=eos,
                      priority=t.priority if priority is None else priority)
        srv.submit(req)
        self._pending[rid] = (model_id, req)
        self._marks[rid] = {"submit": (self.ticks, time.perf_counter())}
        return rid

    # -- scheduling ------------------------------------------------------------

    def _tick_order(self) -> list[str]:
        order: list[str] = []
        n = len(self._cycle)
        for i in range(n):
            mid = self._cycle[(self._rr + i) % n]
            if mid not in order:
                order.append(mid)
        self._rr = (self._rr + 1) % n
        return order

    def step_all(self) -> bool:
        """One global tick: every tenant steps once, in this tick's WRR
        order (earlier = first claim on free pages for admission/resume).
        Returns True while any tenant still has work."""
        busy = False
        for mid in self._tick_order():
            busy = bool(self.servers[mid].step()) or busy
        self.ticks += 1
        self._mark_progress()
        return busy

    def _mark_progress(self):
        now = time.perf_counter()
        done = []
        for rid, (mid, req) in self._pending.items():
            m = self._marks[rid]
            if req.out and "first" not in m:
                m["first"] = (self.ticks, now)
            if req.done:
                m["done"] = (self.ticks, now)
                done.append(rid)
        for rid in done:
            mid, req = self._pending.pop(rid)
            m = self._marks.pop(rid)
            rec = self.slo[mid]
            rec["completed"] += 1
            sub, first = m["submit"], m.get("first", m["done"])
            fin = m["done"]
            rec["ttft_ticks"].append(first[0] - sub[0])
            rec["ttft_s"].append(first[1] - sub[1])
            steps = max(len(req.out) - 1, 1)
            rec["itl_ticks"].append((fin[0] - first[0]) / steps)
            rec["itl_s"].append((fin[1] - first[1]) / steps)

    def run(self) -> int:
        t0 = self.ticks
        while self.step_all():
            pass
        return self.ticks - t0

    def flush_tier(self):
        """Clean shutdown of the tier: park -> store -> disk (so a restarted
        MultiServer re-admits every tenant's flushed prefixes)."""
        if hasattr(self.pt, "flush_cached"):
            self.pt.flush_cached()
            if self.pt.store is not None:
                self.pt.store.flush()

    # -- reporting -------------------------------------------------------------

    def stats(self) -> dict:
        """Per-tenant scheduler + SLO counters, plus shared-pool stats."""
        out = {"pool": self.pt.stats(), "ticks": self.ticks}
        if hasattr(self.pt, "tier_stats"):
            out["tier"] = dict(self.pt.tier_stats)
            if self.pt.store is not None:
                out["store"] = dict(self.pt.store.stats)
        for mid, srv in self.servers.items():
            rec = self.slo[mid]
            out[mid] = {
                **{k: srv.stats[k] for k in
                   ("admitted", "preemptions", "resumes", "shared_pages",
                    "cow_forks", "prefill_skips", "tier_hits_device",
                    "tier_hits_host", "tier_hits_disk")},
                "submitted": rec["submitted"],
                "dropped": rec["dropped"],
                "completed": rec["completed"],
                "jit_signatures": sum(srv.compile_counts.values()),
                "ttft_ticks_p50": _pct(rec["ttft_ticks"], 50),
                "ttft_ticks_p99": _pct(rec["ttft_ticks"], 99),
                "itl_ticks_p50": _pct(rec["itl_ticks"], 50),
                "itl_ticks_p99": _pct(rec["itl_ticks"], 99),
                "ttft_s_p50": _pct(rec["ttft_s"], 50),
                "ttft_s_p99": _pct(rec["ttft_s"], 99),
                "itl_s_p50": _pct(rec["itl_s"], 50),
                "itl_s_p99": _pct(rec["itl_s"], 99),
            }
        return out


def _parse_tenant(spec: str, idx: int, args) -> TenantSpec:
    """CLI tenant spec: ARCH[,POLICY[,SLOTS[,WEIGHT[,PRIORITY]]]]."""
    parts = spec.split(",")
    arch = parts[0]
    policy = parts[1] if len(parts) > 1 and parts[1] else None
    slots = int(parts[2]) if len(parts) > 2 else 2
    weight = int(parts[3]) if len(parts) > 3 else 1
    prio = int(parts[4]) if len(parts) > 4 else 0
    return TenantSpec(model_id=f"{arch}#{idx}", arch=arch, policy=policy,
                      slots=slots, weight=weight, priority=prio,
                      cache_len=args.cache_len, reduced=args.reduced,
                      chunk_tokens=args.chunk_tokens)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenant", action="append", required=True,
                    metavar="ARCH[,POLICY[,SLOTS[,WEIGHT[,PRIO]]]]",
                    help="add a tenant (repeatable); e.g. "
                         "--tenant llama3.2-3b,ternary,2,2,1")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests PER TENANT")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="shared pool size; < sum(slots)*cache_len/page_size "
                         "oversubscribes and tenants compete")
    ap.add_argument("--prefix-share", action="store_true")
    ap.add_argument("--preempt", action="store_true")
    ap.add_argument("--chunk-tokens", type=int, default=0)
    ap.add_argument("--no-dispatch-ahead", dest="dispatch_ahead",
                    action="store_false", default=True)
    ap.add_argument("--tier-dir", default=None,
                    help="enable the tiered prefix cache with this disk-slab "
                         "directory (host tier: --tier-host-slabs); flushed "
                         "at exit so a restart re-admits cached prefixes")
    ap.add_argument("--tier-host-slabs", type=int, default=64)
    ap.add_argument("--tier-watermark", type=int, default=0,
                    help="max device-parked pages (0 = bounded only by "
                         "allocation pressure)")
    ap.add_argument("--jit-budget", type=int, default=None,
                    help="fail if ANY tenant's trace-time signatures exceed "
                         "this (the discipline holds per model entry)")
    ap.add_argument("--expect-tier-hits", type=int, default=None,
                    help="fail unless host+disk tier hits reach this total "
                         "(the CI kill-and-restart reuse gate)")
    args = ap.parse_args(argv)

    tenants = [_parse_tenant(s, i, args) for i, s in enumerate(args.tenant)]
    store = (PageStore(host_capacity=args.tier_host_slabs,
                       disk_dir=args.tier_dir)
             if args.tier_dir is not None else None)
    ms = MultiServer(tenants, page_size=args.page_size,
                     num_pages=args.num_pages,
                     prefix_share=args.prefix_share, preempt=args.preempt,
                     dispatch_ahead=args.dispatch_ahead, tier=store,
                     tier_watermark=args.tier_watermark)
    print(f"tenants: " + ", ".join(
        f"{t.model_id}(policy={ms.servers[t.model_id].cfg.policy}, "
        f"slots={t.slots}, w={t.weight}, prio={t.priority})"
        for t in tenants))
    print(f"shared pool: {ms.pt.usable_pages} usable pages x "
          f"{ms.pt.page_size} tokens"
          + (f", tiered -> {args.tier_dir}" if store else ""))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        for t in tenants:
            vocab = ms.servers[t.model_id].cfg.vocab
            # every tenant's traffic repeats a page-aligned common prefix
            # (stable per tenant AND across process runs), so prefix sharing
            # has something to alias and a restarted run re-probes the same
            # disk-tier keys — namespacing keeps equal token streams
            # distinct across tenants
            prng = np.random.default_rng(zlib.crc32(t.model_id.encode()))
            prompt = np.concatenate([
                prng.integers(0, vocab, size=(args.page_size,)),
                rng.integers(0, vocab, size=(int(rng.integers(2, 7)),))
            ]).astype(np.int32)
            ms.submit(t.model_id, prompt, args.max_new, seed=i)
    ticks = ms.run()
    dt = time.time() - t0
    if store is not None:
        ms.flush_tier()
    st = ms.stats()
    total_done = sum(st[t.model_id]["completed"] for t in tenants)
    total_tok = sum(len(r.out) for t in tenants
                    for r in ms.servers[t.model_id].completed)
    print(f"served {total_done} requests / {total_tok} tokens across "
          f"{len(tenants)} tenants in {ticks} ticks, {dt:.1f}s")
    worst_sigs = 0
    for t in tenants:
        row = st[t.model_id]
        worst_sigs = max(worst_sigs, row["jit_signatures"])
        print(f"  {t.model_id}: admitted={row['admitted']} "
              f"preempt={row['preemptions']} dropped={row['dropped']} "
              f"shared={row['shared_pages']} skips={row['prefill_skips']} "
              f"tier(d/h/k)={row['tier_hits_device']}/"
              f"{row['tier_hits_host']}/{row['tier_hits_disk']} "
              f"ttft p50/p99={row['ttft_ticks_p50']:.0f}/"
              f"{row['ttft_ticks_p99']:.0f} ticks "
              f"itl p50/p99={row['itl_ticks_p50']:.2f}/"
              f"{row['itl_ticks_p99']:.2f} ticks "
              f"jit={row['jit_signatures']}")
    peak = max(s.stats["peak_pages"] for s in ms.servers.values())
    print(f"pool: occupancy peak {peak / ms.pt.usable_pages:.2f}, exit "
          f"{st['pool']['occupancy']:.2f} ({st['pool']['live_pages']}/"
          f"{st['pool']['usable_pages']} usable live)"
          + (f", parked {st['pool'].get('cached_pages', 0)}" if store else ""))
    if store is not None:
        tier_hits = sum(st[t.model_id]["tier_hits_host"]
                        + st[t.model_id]["tier_hits_disk"] for t in tenants)
        print(f"tier: {st['tier']} store={st['store']} "
              f"promoted-hits={tier_hits}")
        if (args.expect_tier_hits is not None
                and tier_hits < args.expect_tier_hits):
            raise SystemExit(f"expected >= {args.expect_tier_hits} host/disk "
                             f"tier hits, measured {tier_hits}")
    elif args.expect_tier_hits is not None:
        raise SystemExit("--expect-tier-hits needs --tier-dir")
    if args.jit_budget is not None and worst_sigs > args.jit_budget:
        raise SystemExit(f"jit budget exceeded: a tenant traced {worst_sigs} "
                         f"signatures > per-model budget {args.jit_budget}")
    return ms


if __name__ == "__main__":
    main()
