"""Production mesh definitions.

Functions, not module-level constants, so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init; smoke
tests and benches must keep seeing 1 device).

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod : 2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism over the inter-pod (DCI) links; "model" stays
inside the pod where ICI bandwidth lives.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, *, model: int = 1):
    """Small mesh over the actually-present devices (tests, examples)."""
    devs = jax.devices()
    n = n or len(devs)
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


# TPU v5e hardware constants used by the roofline (benchmarks read these)
PEAK_FLOPS_BF16 = 197e12          # per chip
PEAK_OPS_INT8 = 394e12            # per chip (MXU int8)
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per-chip usable)
VMEM_BYTES = 128 * 2 ** 20        # ~128 MiB VMEM per chip
HBM_BYTES = 16 * 2 ** 30          # 16 GiB HBM per chip
