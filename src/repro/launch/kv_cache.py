"""Paged KV cache for the continuous-batching server (launch/serve.py).

vLLM-style block pool, shrunk to its essentials: every full-attention layer
stores KV in a shared `(num_pages, page_size, Hk, dh)` pool instead of a
per-slot `(slots, cache_len, Hk, dh)` slab, and a host-side `PageTable` maps
each slot to the ordered list of physical pages backing its logical token
range. The model side (models/attention.attn_decode with `pages=`) gathers a
slot's page list back into a contiguous view for the score/AV math, so the
attention algebra is unchanged — only the storage is virtualized.

Pages are **refcounted** and indexed by a rolling content hash of the token
prefix they cover (`prefix_keys`), so requests that share a prompt prefix can
map the *same* physical pages (prefix sharing). A shared page is immutable to
its sharers: before a slot's decode write lands inside a page with
refcount > 1, the scheduler forks it — allocate a fresh page, copy the bytes
(`copy_page`), remap the writer — copy-on-write. Preemption swaps a victim's
pages out to a host-side numpy slab (`swap_out_slot`) and frees them; resume
re-allocates pages and scatters the bytes back (`swap_in_slot`), token-exact.

Why it matters here: BrainTTA's pitch is one flexible datapath serving
binary/ternary/int8 from the same engine; the serving layer above it only
keeps that engine fed under mixed-length traffic if KV memory is allocated by
demand (pages), deduplicated across requests (prefix sharing), and
reclaimable under pressure (preemption + swap) rather than reserved by worst
case.

Layout invariants (property-tested in tests/test_kv_cache.py):
  * physical page 0 is reserved as scratch — never allocated; unassigned
    page-table entries point at it, so inactive slots' decode writes and
    reads beyond a slot's length land there and are masked out
  * refcount[p] == number of (slot, index) table entries mapping p; a page
    is freed exactly when its refcount hits zero
  * free + distinct-owned == num_pages - 1
  * a slot holding n tokens maps exactly ceil(n / page_size) pages
  * every hash-indexed page has refcount >= 1 (freed pages leave the index)
  * retire()/swap_out() drop one reference per mapped page; CoW fork leaves
    the source bytes untouched and gives the writer a refcount-1 copy

Sharing correctness rests on determinism: a token's KV depends only on the
token-id prefix before it (causal attention, no dropout at serve), so two
requests whose prompts agree through a page boundary compute bit-identical
KV for that page and may alias it. The key for page i is a rolling hash over
tokens[0 : min((i+1)*P, n)] — the *whole* prefix, not just the page's own
tokens — because attention makes page content a function of everything
before it. The final partial prompt page is keyed too (by the exact covered
prefix), which is what makes CoW load-bearing: identical prompts alias their
boundary page and fork it as soon as their sampled continuations diverge.

Recurrent mixers (mlstm/slstm/rglru) and sliding-window rings keep per-slot
state slabs — their state is O(1) or O(window) per slot, so there is nothing
to page or share; the PageTable still meters their token budget for
admission, and preemption swaps their slab rows alongside the pages.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NULL_PAGE = 0   # reserved scratch page: garbage writes land here, reads are masked
_ROOT = -1      # share-index chain parent of every prompt's first page

_FNV_OFFSET = 0xcbf29ce484222325
_FNV_PRIME = 0x100000001b3
_MASK64 = (1 << 64) - 1


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold n_tokens."""
    return -(-int(n_tokens) // page_size)


def prefix_keys(tokens, page_size: int, *,
                namespace: bytes = b"") -> list[tuple[int, int, bytes]]:
    """Content keys for prefix sharing, one per page.

    Key for page i is `(covered, fnv64(prefix), own_page_bytes)` with
    `covered = min((i+1)*page_size, len(tokens))` — a rolling FNV-1a chain
    over the *whole* prefix `tokens[0:covered]` (the page's KV depends on
    everything before it, so the hash must too), plus the verbatim bytes of
    the page's OWN tokens only. The exact covered length means a page
    holding k prompt tokens only matches a request whose prompt covers
    exactly those k tokens (a longer prompt that merely starts the same gets
    a different key for its partial page).

    Exactness without O(n²) key material: the share index composes each key
    with the *parent physical page* of the preceding prefix page
    (vLLM-style block chaining). By induction, an index hit therefore proves
    the full prefix matches verbatim — parent identity pins tokens[0:i*P]
    exactly, own bytes pin the rest — so a 64-bit hash collision between
    different prompts can never alias one request's KV pages into another's.
    Total key material per prompt is O(n) and the chain hash is just a fast
    prefilter that makes unequal tuples fail comparison early.

    `namespace` (multi-tenant serving): a model-id byte string absorbed into
    the rolling-hash root AND prepended to every key's verbatim bytes. KV is
    a function of (weights, tokens), so two models must never alias a page
    even for identical token streams — namespacing makes their key spaces
    disjoint at both the hash prefilter and the exact-bytes comparison.
    """
    keys: list[tuple[int, int, bytes]] = []
    h = _FNV_OFFSET
    for b in bytes(namespace):
        h = ((h ^ b) * _FNV_PRIME) & _MASK64
    toks = np.ascontiguousarray(np.asarray(tokens, np.int64))
    for i in range(toks.shape[0]):
        h = ((h ^ (int(toks[i]) & _MASK64)) * _FNV_PRIME) & _MASK64
        if (i + 1) % page_size == 0 or i + 1 == toks.shape[0]:
            start = (i // page_size) * page_size
            keys.append((i + 1, h,
                         bytes(namespace) + toks[start: i + 1].tobytes()))
    return keys


class PageTable:
    """Host-side block-pool allocator: per-slot ordered page lists, page
    refcounts, and a prefix-hash share index.

    Everything here is host numpy/dicts — refcounts, the free list, the hash
    index, and swap bookkeeping never live on device. The device-side mirror
    (`device_table()`) is a dense (slots, max_pages) int32 array — a fixed
    shape, so the jitted decode step never retraces as pages move, fork, or
    swap.
    """

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 max_pages_per_slot: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        if page_size < 1 or max_pages_per_slot < 1:
            raise ValueError("page_size and max_pages_per_slot must be >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.max_pages = int(max_pages_per_slot)
        # LIFO free list: retired pages are reused first (cache-friendly)
        self._free = list(range(self.num_pages - 1, 0, -1))
        self.table = np.full((self.slots, self.max_pages), NULL_PAGE, np.int32)
        self.held = np.zeros(self.slots, np.int32)     # pages mapped per slot
        self.tokens = np.zeros(self.slots, np.int32)   # tokens covered per slot
        self.active = np.zeros(self.slots, bool)
        self.refcount = np.zeros(self.num_pages, np.int32)
        self._index: dict = {}      # prefix key -> physical page
        self._page_key: dict = {}   # physical page -> prefix key (reverse)

    # -- queries ---------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    def stats(self) -> dict:
        """Pool occupancy over *usable* pages: page 0 is reserved scratch
        (never allocatable) and inert phys-slot padding rows never map pages,
        so neither is real demand — `occupancy` is live/(num_pages-1), which
        is what a utilization column should report (the raw num_pages
        denominator understated pressure by the scratch page and the old
        peak-vs-num_pages bench column overstated headroom)."""
        usable = self.usable_pages
        live = usable - self.free_pages
        return {"usable_pages": usable, "free_pages": self.free_pages,
                "live_pages": live,
                "occupancy": live / usable if usable else 0.0}

    def can_admit(self, n_tokens: int, *, reclaimable: int = 0) -> bool:
        """Whether n_tokens' pages fit the free list. `reclaimable` counts
        pages held by lower-priority *preemptable* running requests — the
        server passes it when `--preempt` is on, so admission stops rejecting
        work the scheduler could make room for by swapping a victim out. It
        may overcount (a victim's shared pages survive its preemption), so
        callers must still verify the free list after actually preempting."""
        return self.free_pages + int(reclaimable) >= pages_for(n_tokens,
                                                               self.page_size)

    def lookup_keys(self, keys) -> list:
        """Share-index probe: physical page per key, or None on a miss.

        Keys compose with the PARENT physical page of the preceding prefix
        page (`_ROOT` for page 0), so a hit proves the whole prefix chain
        matches — see `prefix_keys`. A broken chain cannot resume: sharing
        is prefix-closed (every owner of page i also maps page i-1, so a
        live indexed page always has a live parent)."""
        out: list = []
        parent = _ROOT
        for k in keys:
            hit = self._index.get((parent, k))
            out.append(hit)
            if hit is None:
                out.extend([None] * (len(keys) - len(out)))
                break
            parent = hit
        return out

    def slot_pages(self, slot: int) -> np.ndarray:
        return self.table[slot, : self.held[slot]].copy()

    def cow_pending(self, slot: int, token_pos: int,
                    extra_shared=frozenset()) -> bool:
        """True iff writing `token_pos` for `slot` would land in a page the
        slot shares (refcount > 1) — i.e. `fork_cow` will need one free page
        before the decode write. `extra_shared` lets admission ask the
        hypothetical "...or would share, if these pages gain a co-owner"
        (the server's fork-debt reservation), so the write-page rule lives
        in exactly one place."""
        idx = int(token_pos) // self.page_size
        if not self.active[slot] or idx >= int(self.held[slot]):
            return False
        pid = int(self.table[slot, idx])
        return int(self.refcount[pid]) > 1 or pid in extra_shared

    def device_table(self) -> jnp.ndarray:
        return jnp.asarray(self.table)

    # -- mutations -------------------------------------------------------------

    def _take_page(self) -> int:
        if not self._free:
            raise RuntimeError("page pool exhausted: want 1, free 0")
        p = self._free.pop()
        self.refcount[p] = 1
        return p

    def _alloc(self, slot: int, n_pages: int) -> list[int]:
        if n_pages > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n_pages}, free {len(self._free)}")
        got = [self._take_page() for _ in range(n_pages)]
        h = int(self.held[slot])
        self.table[slot, h: h + n_pages] = got
        self.held[slot] = h + n_pages
        return got

    def _map_page(self, slot: int, page: int):
        """Map an existing (indexed) page into the slot: one more reference."""
        h = int(self.held[slot])
        self.table[slot, h] = page
        self.held[slot] = h + 1
        self.refcount[page] += 1

    def _register_key(self, parent, key, page: int):
        """Register `page` in the share index under `(parent, key)`. The
        single write point for index entries — cache_tiers.TieredPageTable
        overrides it to record the page's namespace and verbatim prefix
        chain (its content address in the host/disk tiers)."""
        self._index[(parent, key)] = page
        self._page_key[page] = (parent, key)

    def _drop_page(self, page: int) -> bool:
        """Drop one reference; free the page iff the count hits zero (and
        evict its share-index entry — a free page must never be findable)."""
        self.refcount[page] -= 1
        if self.refcount[page] > 0:
            return False
        key = self._page_key.pop(page, None)
        if key is not None:
            self._index.pop(key, None)
        self._free.append(int(page))
        return True

    def _check_admit(self, slot: int, n_tokens: int):
        if self.active[slot]:
            raise RuntimeError(f"slot {slot} already active")
        if n_tokens < 1 or n_tokens > self.max_pages * self.page_size:
            raise ValueError(
                f"n_tokens={n_tokens} outside (0, {self.max_pages * self.page_size}]")

    def admit(self, slot: int, n_tokens: int) -> np.ndarray:
        """Claim `slot` and allocate private pages covering n_tokens.
        Returns the slot's page list."""
        self._check_admit(slot, n_tokens)
        if not self.can_admit(n_tokens):
            raise RuntimeError(
                f"page pool exhausted: want {pages_for(n_tokens, self.page_size)},"
                f" free {self.free_pages}")
        self.active[slot] = True
        self._alloc(slot, pages_for(n_tokens, self.page_size))
        self.tokens[slot] = n_tokens
        return self.slot_pages(slot)

    def admit_shared(self, slot: int, n_tokens: int, keys, *,
                     defer_index: bool = False
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Claim `slot`, mapping share-index hits and allocating the misses.

        `keys` is one `prefix_keys` entry per page (must be distinct — the
        rolling chain guarantees it for real prompts). Returns
        `(page_ids, shared)` where `shared[i]` marks pages mapped from the
        index — the caller must NOT scatter prefill KV into those (their
        bytes already hold the shared prefix, and may hold a co-owner's live
        decode tokens past the key's coverage). Newly allocated pages are
        registered under their key for future admissions to hit — unless
        `defer_index` is set: chunked prefill writes page bytes chunk by
        chunk AFTER admission, and an indexed page must never be mappable
        before its bytes exist, so the server registers progressively via
        `index_pages` as chunks land instead.
        """
        need = pages_for(n_tokens, self.page_size)
        if len(keys) != need:
            raise ValueError(f"need {need} keys, got {len(keys)}")
        self._check_admit(slot, n_tokens)
        hits = self.lookup_keys(keys)
        misses = sum(1 for p in hits if p is None)
        if self.free_pages < misses:
            raise RuntimeError(
                f"page pool exhausted: want {misses}, free {self.free_pages}")
        self.active[slot] = True
        shared = np.zeros(need, bool)
        parent = _ROOT
        for i, (key, hit) in enumerate(zip(keys, hits)):
            if hit is not None:
                self._map_page(slot, hit)
                shared[i] = True
                parent = hit
            else:
                (page,) = self._alloc(slot, 1)
                if not defer_index:
                    self._register_key(parent, key, page)
                parent = page
        self.tokens[slot] = n_tokens
        return self.slot_pages(slot), shared

    def index_pages(self, slot: int, keys, covered: int):
        """Deferred share-index registration (pairs with
        `admit_shared(defer_index=True)`): register the slot's leading pages
        whose key coverage lies within `covered` prompt tokens — i.e. whose
        bytes the chunked prefill has now written. Idempotent: call after
        every chunk with the growing `covered`; already-registered pages
        (including shared hits mapped at admission) just advance the chain
        parent. The final partial page's key covers the whole prompt, so it
        registers only once the prefill completes — exactly when its bytes
        match what the key promises.

        If another slot won a registration race for the same (parent, key)
        (two identical prompts admitted concurrently past the server's
        deferral heuristic), this slot's duplicate page stays private and
        registration stops — entries chained past an unregistered page would
        be unreachable by `lookup_keys` anyway."""
        if not self.active[slot]:
            raise RuntimeError(f"slot {slot} not active")
        parent = _ROOT
        for i, key in enumerate(keys):
            if i >= int(self.held[slot]) or key[0] > int(covered):
                break
            page = int(self.table[slot, i])
            have = self._page_key.get(page)
            if have is None:
                if (parent, key) in self._index:
                    break                      # lost the race: stay private
                self._register_key(parent, key, page)
            parent = page

    def extend(self, slot: int, n_tokens: int) -> list[int]:
        """Grow slot coverage to n_tokens; returns newly allocated (private,
        unindexed) pages — decode growth is per-request, never shared."""
        if not self.active[slot]:
            raise RuntimeError(f"slot {slot} not active")
        if n_tokens > self.max_pages * self.page_size:
            raise ValueError(f"n_tokens={n_tokens} exceeds slot capacity")
        if n_tokens <= self.tokens[slot]:
            return []
        need = pages_for(n_tokens, self.page_size) - int(self.held[slot])
        got = self._alloc(slot, need) if need > 0 else []
        self.tokens[slot] = n_tokens
        return got

    def fork_cow(self, slot: int, token_pos: int) -> tuple[int, int] | None:
        """Copy-on-write fork before `slot` writes `token_pos`.

        If the page backing token_pos is shared (refcount > 1), allocate a
        fresh page, remap the slot's table entry to it, drop one reference on
        the source, and return `(src, dst)` — the caller MUST copy the page
        bytes device-side (`copy_page`) before the decode write runs. Returns
        None when the page is exclusively owned (write in place; a solely
        owned indexed page may grow decode bytes past its key's coverage —
        safe, because a future sharer's validity mask only reaches tokens it
        wrote or the keyed prefix, and it overwrites-before-read beyond it).
        The fork is never indexed: it diverges immediately.
        """
        if not self.active[slot]:
            raise RuntimeError(f"slot {slot} not active")
        idx = int(token_pos) // self.page_size
        if idx >= int(self.held[slot]):
            return None                      # next write opens a fresh page
        src = int(self.table[slot, idx])
        if self.refcount[src] <= 1:
            return None
        dst = self._take_page()
        self.table[slot, idx] = dst
        self.refcount[src] -= 1              # never hits 0 here (was > 1)
        return src, dst

    def _release(self, slot: int) -> list[int]:
        freed = [int(p) for p in self.table[slot, : self.held[slot]]
                 if self._drop_page(p)]
        self.table[slot] = NULL_PAGE
        self.held[slot] = 0
        self.tokens[slot] = 0
        self.active[slot] = False
        return freed

    def retire(self, slot: int) -> list[int]:
        """Release the slot; pages whose refcount hits zero return to the
        free list (shared pages survive for their co-owners)."""
        if not self.active[slot]:
            raise RuntimeError(f"slot {slot} not active")
        return self._release(slot)

    def swap_out(self, slot: int) -> list[int]:
        """Preemption: release the slot's mapping (same page accounting as
        retire). The caller must gather the slot's page bytes to the host
        slab BEFORE calling this — the freed pages are immediately reusable."""
        if not self.active[slot]:
            raise RuntimeError(f"slot {slot} not active")
        return self._release(slot)

    def swap_in(self, slot: int, n_tokens: int) -> np.ndarray:
        """Resume a preempted request: allocate fresh private pages covering
        its saved n_tokens (the caller scatters the host slab back into
        them). Swapped-in pages are not re-registered in the share index —
        the request's decode tail has already diverged from any prefix key."""
        return self.admit(slot, n_tokens)

    def view(self, base: int, slots: int, namespace: bytes = b"") -> "SlotView":
        """A slot-window view for multi-tenant serving: slots
        [base, base+slots) re-addressed from 0, sharing this table's page
        pool, refcounts and share index. See `SlotView`."""
        return SlotView(self, base, slots, namespace)


class SlotView:
    """One tenant's window onto a shared `PageTable`.

    The multi-tenant server gives every tenant `Server` a contiguous slot
    range of ONE PageTable; the view re-addresses those slots from 0 so the
    per-tenant scheduler code runs unchanged, while the free list, refcounts
    and the prefix-share index stay global — that is the whole point: all
    tenants allocate from (and index into) the same pool. `table`/`held`/
    `tokens`/`active` are numpy basic slices of the parent arrays (views,
    not copies), so parent-side mutations are visible through the view and
    vice versa. Index-writing calls stamp the parent's current namespace
    first, so a tiered table records which tenant's cache pool each indexed
    page's bytes live in (the demotion gather needs the right pool).
    """

    def __init__(self, pt: PageTable, base: int, slots: int,
                 namespace: bytes = b""):
        if base < 0 or base + slots > pt.slots:
            raise ValueError(f"view [{base}, {base + slots}) outside "
                             f"{pt.slots} slots")
        self._pt = pt
        self._base = int(base)
        self.slots = int(slots)
        self.namespace = bytes(namespace)
        sl = slice(self._base, self._base + self.slots)
        self.table = pt.table[sl]
        self.held = pt.held[sl]
        self.tokens = pt.tokens[sl]
        self.active = pt.active[sl]

    def __getattr__(self, name):
        # global (non-slot-indexed) state delegates untranslated: free_pages,
        # refcount, num_pages, page_size, max_pages, lookup_keys, can_admit,
        # stats, and the tier surface (store, adopt, tier_stats, ...)
        return getattr(self._pt, name)

    def _stamp_ns(self):
        self._pt._current_ns = self.namespace

    def slot_pages(self, slot):
        return self._pt.slot_pages(self._base + slot)

    def cow_pending(self, slot, token_pos, extra_shared=frozenset()):
        return self._pt.cow_pending(self._base + slot, token_pos,
                                    extra_shared)

    def admit(self, slot, n_tokens):
        return self._pt.admit(self._base + slot, n_tokens)

    def admit_shared(self, slot, n_tokens, keys, *, defer_index=False):
        self._stamp_ns()
        return self._pt.admit_shared(self._base + slot, n_tokens, keys,
                                     defer_index=defer_index)

    def index_pages(self, slot, keys, covered):
        self._stamp_ns()
        return self._pt.index_pages(self._base + slot, keys, covered)

    def extend(self, slot, n_tokens):
        return self._pt.extend(self._base + slot, n_tokens)

    def fork_cow(self, slot, token_pos):
        return self._pt.fork_cow(self._base + slot, token_pos)

    def retire(self, slot):
        return self._pt.retire(self._base + slot)

    def swap_out(self, slot):
        return self._pt.swap_out(self._base + slot)

    def swap_in(self, slot, n_tokens):
        return self._pt.swap_in(self._base + slot, n_tokens)


# ---------------------------------------------------------------------------
# cache-tree helpers (which leaves are paged, prefill scatter, CoW copy, swap)
# ---------------------------------------------------------------------------

def paged_leaf_mask(cfg, slots: int, cache_len: int, num_pages: int,
                    page_size: int):
    """Bool pytree (same structure as the server cache): True on the KV
    leaves that live in the page pool. Derived by diffing the slab vs paged
    shape trees, so it tracks whatever layer mix the arch has (window rings
    and recurrent states come back False)."""
    from repro.models import transformer
    slab = transformer.cache_shapes(cfg, slots, cache_len)
    pgd = transformer.cache_shapes(cfg, slots, cache_len,
                                   paged=(num_pages, page_size))
    return jax.tree.map(lambda a, b: a.shape != b.shape, slab, pgd)


def _is_mid(path) -> bool:
    return bool(path) and getattr(path[0], "key", "") == "mid"


def scatter_prefill(cache, req_cache, slot: int, *, paged_mask=None,
                    page_ids=None, page_size: int = 0):
    """Write one request's prefill cache (batch=1) into the server cache.

    Slab leaves (recurrent state, window rings, cross-KV) copy into row
    `slot`; paged leaves chop the request's contiguous KV into page_size
    chunks and scatter them to `page_ids` (physical pages; entries equal to
    NULL_PAGE receive this request's right-padding garbage, which is fine —
    page 0 is scratch). Prefix-shared pages are passed as NULL_PAGE too: the
    shared physical page already holds this prefix's KV and may hold a
    co-owner's decode tokens past it, so it must not be rewritten. Scanned
    mid-stack leaves carry a leading (n_periods,) dim and are handled in
    place.
    """
    ids = None if page_ids is None else jnp.asarray(page_ids, jnp.int32)

    def put(path, slab, req, is_paged):
        mid = _is_mid(path)
        if is_paged:
            n = ids.shape[0]
            if mid:
                body = req[:, 0, : n * page_size].astype(slab.dtype)
                return slab.at[:, ids].set(
                    body.reshape(body.shape[0], n, page_size, *body.shape[2:]))
            body = req[0, : n * page_size].astype(slab.dtype)
            return slab.at[ids].set(body.reshape(n, page_size, *body.shape[1:]))
        if mid:
            return slab.at[:, slot].set(req[:, 0].astype(slab.dtype))
        return slab.at[slot].set(req[0].astype(slab.dtype))

    if paged_mask is None:
        paged_mask = jax.tree.map(lambda _: False, cache)
    return jax.tree_util.tree_map_with_path(put, cache, req_cache, paged_mask)


def copy_page(cache, src, dst, paged_mask):
    """Copy physical page `src` -> `dst` on every paged leaf (the CoW fork's
    byte copy). `src`/`dst` are scalar int32s, so the jitted signature is
    fixed — fork traffic never retraces. Slab leaves pass through untouched."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cp(path, leaf, is_paged):
        if not is_paged:
            return leaf
        if _is_mid(path):
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf.at[dst].set(leaf[src])

    return jax.tree_util.tree_map_with_path(cp, cache, paged_mask)


def swap_out_slot(cache, slot: int, page_ids, paged_mask):
    """Gather one slot's cache state into a host-side numpy slab (swap-out).

    Paged leaves gather the slot's page list `(n_pages, page_size, …)`; slab
    leaves (window rings, recurrent state, cross-KV) take row `slot`. The
    result is plain numpy — swap slabs live host-side by design (they are
    spilled capacity, not working set), and `np.asarray` of a device array is
    a bit-exact copy in the pool dtype, so swap round-trips token-exactly.
    Shared pages may carry a co-owner's decode bytes past this slot's
    coverage; they ride along harmlessly (masked on resume, then overwritten).
    """
    ids = jnp.asarray(page_ids, jnp.int32)

    def grab(path, leaf, is_paged):
        if is_paged:
            return np.asarray(leaf[:, ids] if _is_mid(path) else leaf[ids])
        return np.asarray(leaf[:, slot] if _is_mid(path) else leaf[slot])

    return jax.tree_util.tree_map_with_path(grab, cache, paged_mask)


def gather_pages(cache, page_ids, paged_mask):
    """Gather the bytes of specific physical pages into a host numpy pytree
    (paged leaves only — slab leaves come back as zero-size placeholders; a
    page is pure pool state, it has no per-slot rows). The cache-tier
    demotion path: a refcount-0 indexed page's bytes leave the device pool
    through here before the page id is reused. Inverse: `scatter_pages`."""
    ids = jnp.asarray(page_ids, jnp.int32)

    def grab(path, leaf, is_paged):
        if not is_paged:
            return np.zeros(0, np.int8)
        return np.asarray(leaf[:, ids] if _is_mid(path) else leaf[ids])

    return jax.tree_util.tree_map_with_path(grab, cache, paged_mask)


def scatter_pages(cache, saved, page_ids, paged_mask):
    """Scatter a `gather_pages` image back into specific physical pages (the
    cache-tier promotion path: a host/disk slab re-materializes into a
    freshly allocated page). Slab leaves (zero-size placeholders in the
    saved tree) pass through untouched."""
    ids = jnp.asarray(page_ids, jnp.int32)

    def put(path, leaf, sv, is_paged):
        if not is_paged:
            return leaf
        body = jnp.asarray(sv, leaf.dtype)
        if _is_mid(path):
            return leaf.at[:, ids].set(body)
        return leaf.at[ids].set(body)

    return jax.tree_util.tree_map_with_path(put, cache, saved, paged_mask)


def swap_in_slot(cache, saved, slot: int, page_ids, paged_mask):
    """Scatter a swapped-out slab back into the cache (swap-in): paged leaves
    to the freshly allocated `page_ids`, slab leaves to row `slot` (the
    resume slot may differ from the original). Inverse of `swap_out_slot`;
    runs unjitted (page counts vary per request, and swaps are rare)."""
    ids = jnp.asarray(page_ids, jnp.int32)

    def put(path, leaf, sv, is_paged):
        body = jnp.asarray(sv, leaf.dtype)
        if is_paged:
            if _is_mid(path):
                return leaf.at[:, ids].set(body)
            return leaf.at[ids].set(body)
        if _is_mid(path):
            return leaf.at[:, slot].set(body)
        return leaf.at[slot].set(body)

    return jax.tree_util.tree_map_with_path(put, cache, saved, paged_mask)
