"""Paged KV cache for the continuous-batching server (launch/serve.py).

vLLM-style block pool, shrunk to its essentials: every full-attention layer
stores KV in a shared `(num_pages, page_size, Hk, dh)` pool instead of a
per-slot `(slots, cache_len, Hk, dh)` slab, and a host-side `PageTable` maps
each slot to the ordered list of physical pages backing its logical token
range. The model side (models/attention.attn_decode with `pages=`) gathers a
slot's page list back into a contiguous view for the score/AV math, so the
attention algebra is unchanged — only the storage is virtualized.

Why it matters here: BrainTTA's pitch is one flexible datapath serving
binary/ternary/int8 from the same engine; the serving layer above it only
keeps that engine fed under mixed-length traffic if KV memory is allocated by
demand (pages) rather than by worst case (slabs). Admission then becomes a
free-page budget, not a free-slot count.

Layout invariants (property-tested in tests/test_kv_cache.py):
  * physical page 0 is reserved as scratch — never allocated; unassigned
    page-table entries point at it, so inactive slots' decode writes and
    reads beyond a slot's length land there and are masked out
  * a page is owned by at most one slot; free + owned == num_pages - 1
  * a slot holding n tokens owns exactly ceil(n / page_size) pages
  * retire() returns every page to the free list

Recurrent mixers (mlstm/slstm/rglru) and sliding-window rings keep per-slot
state slabs — their state is O(1) or O(window) per slot, so there is nothing
to page; the PageTable still meters their token budget for admission.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NULL_PAGE = 0   # reserved scratch page: garbage writes land here, reads are masked


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold n_tokens."""
    return -(-int(n_tokens) // page_size)


class PageTable:
    """Host-side block-pool allocator: per-slot ordered page lists.

    The device-side mirror (`device_table()`) is a dense (slots, max_pages)
    int32 array — a fixed shape, so the jitted decode step never retraces as
    pages move.
    """

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 max_pages_per_slot: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        if page_size < 1 or max_pages_per_slot < 1:
            raise ValueError("page_size and max_pages_per_slot must be >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.max_pages = int(max_pages_per_slot)
        # LIFO free list: retired pages are reused first (cache-friendly)
        self._free = list(range(self.num_pages - 1, 0, -1))
        self.table = np.full((self.slots, self.max_pages), NULL_PAGE, np.int32)
        self.held = np.zeros(self.slots, np.int32)     # pages owned per slot
        self.tokens = np.zeros(self.slots, np.int32)   # tokens covered per slot
        self.active = np.zeros(self.slots, bool)

    # -- queries ---------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    def can_admit(self, n_tokens: int) -> bool:
        return self.free_pages >= pages_for(n_tokens, self.page_size)

    def slot_pages(self, slot: int) -> np.ndarray:
        return self.table[slot, : self.held[slot]].copy()

    def device_table(self) -> jnp.ndarray:
        return jnp.asarray(self.table)

    # -- mutations -------------------------------------------------------------

    def _alloc(self, slot: int, n_pages: int) -> list[int]:
        if n_pages > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n_pages}, free {len(self._free)}")
        got = [self._free.pop() for _ in range(n_pages)]
        h = int(self.held[slot])
        self.table[slot, h: h + n_pages] = got
        self.held[slot] = h + n_pages
        return got

    def admit(self, slot: int, n_tokens: int) -> np.ndarray:
        """Claim `slot` and allocate pages covering n_tokens. Returns the
        slot's page list."""
        if self.active[slot]:
            raise RuntimeError(f"slot {slot} already active")
        if n_tokens < 1 or n_tokens > self.max_pages * self.page_size:
            raise ValueError(
                f"n_tokens={n_tokens} outside (0, {self.max_pages * self.page_size}]")
        if not self.can_admit(n_tokens):
            raise RuntimeError(
                f"page pool exhausted: want {pages_for(n_tokens, self.page_size)},"
                f" free {self.free_pages}")
        self.active[slot] = True
        self._alloc(slot, pages_for(n_tokens, self.page_size))
        self.tokens[slot] = n_tokens
        return self.slot_pages(slot)

    def extend(self, slot: int, n_tokens: int) -> list[int]:
        """Grow slot coverage to n_tokens; returns newly allocated pages."""
        if not self.active[slot]:
            raise RuntimeError(f"slot {slot} not active")
        if n_tokens > self.max_pages * self.page_size:
            raise ValueError(f"n_tokens={n_tokens} exceeds slot capacity")
        if n_tokens <= self.tokens[slot]:
            return []
        need = pages_for(n_tokens, self.page_size) - int(self.held[slot])
        got = self._alloc(slot, need) if need > 0 else []
        self.tokens[slot] = n_tokens
        return got

    def retire(self, slot: int) -> list[int]:
        """Release the slot; every page goes back to the free list."""
        if not self.active[slot]:
            raise RuntimeError(f"slot {slot} not active")
        freed = [int(p) for p in self.table[slot, : self.held[slot]]]
        self._free.extend(freed)
        self.table[slot] = NULL_PAGE
        self.held[slot] = 0
        self.tokens[slot] = 0
        self.active[slot] = False
        return freed


# ---------------------------------------------------------------------------
# cache-tree helpers (which leaves are paged, prefill scatter)
# ---------------------------------------------------------------------------

def paged_leaf_mask(cfg, slots: int, cache_len: int, num_pages: int,
                    page_size: int):
    """Bool pytree (same structure as the server cache): True on the KV
    leaves that live in the page pool. Derived by diffing the slab vs paged
    shape trees, so it tracks whatever layer mix the arch has (window rings
    and recurrent states come back False)."""
    from repro.models import transformer
    slab = transformer.cache_shapes(cfg, slots, cache_len)
    pgd = transformer.cache_shapes(cfg, slots, cache_len,
                                   paged=(num_pages, page_size))
    return jax.tree.map(lambda a, b: a.shape != b.shape, slab, pgd)


def scatter_prefill(cache, req_cache, slot: int, *, paged_mask=None,
                    page_ids=None, page_size: int = 0):
    """Write one request's prefill cache (batch=1) into the server cache.

    Slab leaves (recurrent state, window rings, cross-KV) copy into row
    `slot`; paged leaves chop the request's contiguous KV into page_size
    chunks and scatter them to `page_ids` (physical pages; entries equal to
    NULL_PAGE receive this request's right-padding garbage, which is fine —
    page 0 is scratch). Scanned mid-stack leaves carry a leading
    (n_periods,) dim and are handled in place.
    """
    ids = None if page_ids is None else jnp.asarray(page_ids, jnp.int32)

    def put(path, slab, req, is_paged):
        root = getattr(path[0], "key", "") if path else ""
        mid = root == "mid"
        if is_paged:
            n = ids.shape[0]
            if mid:
                body = req[:, 0, : n * page_size].astype(slab.dtype)
                return slab.at[:, ids].set(
                    body.reshape(body.shape[0], n, page_size, *body.shape[2:]))
            body = req[0, : n * page_size].astype(slab.dtype)
            return slab.at[ids].set(body.reshape(n, page_size, *body.shape[1:]))
        if mid:
            return slab.at[:, slot].set(req[:, 0].astype(slab.dtype))
        return slab.at[slot].set(req[0].astype(slab.dtype))

    if paged_mask is None:
        paged_mask = jax.tree.map(lambda _: False, cache)
    return jax.tree_util.tree_map_with_path(put, cache, req_cache, paged_mask)
