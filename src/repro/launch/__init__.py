"""Distributed launch layer: production meshes, sharding rules, the multi-pod
dry-run, roofline analysis, and the fault-tolerant train/serve drivers."""
from . import mesh, roofline, sharding, step  # noqa: F401
