"""Step builders: the jitted train / prefill / decode steps with their
sharding trees. These are what the dry-run lowers and what train.py/serve.py
execute.

train_step = microbatched (lax.scan) grad accumulation -> AdamW update.
GSPMD inserts the TP/FSDP collectives from the param shardings; the pod axis
sees only gradient all-reduces (sharding.py). An optional int8-compressed
gradient all-reduce variant (shard_map manual over dp axes, auto over model)
is provided for non-FSDP configs — the §Perf collective lever.
"""
from __future__ import annotations

import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.models import registry, transformer
from repro.models.common import ModelCtx
from repro.optim import adamw as adamw_mod
from repro.optim import compress
from repro.optim.adamw import adamw, apply_updates, cosine_schedule

from . import sharding
from .mesh import dp_axes


def make_optimizer(cfg: ArchConfig, *, peak_lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000):
    return adamw(cosine_schedule(peak_lr, warmup, total),
                 int8_state=cfg.opt_state_int8)


def make_train_step(cfg: ArchConfig, sp, opt, *, microbatches: int | None = None,
                    grad_compress: bool = False, ctx: ModelCtx | None = None,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch, rng) -> (params, opt, metrics).

    The global batch is split into `microbatches` chunks accumulated with a
    lax.scan (bounds activation memory; DESIGN.md §3). `grad_shardings`
    (param-sharding tree) pins the per-microbatch gradients and the
    accumulator to the parameter layout, so each microbatch contributes via a
    reduce-scatter into the shard instead of a full all-reduce."""
    ctx = ctx or ModelCtx(mode="train")
    mb = microbatches or cfg.microbatches

    def loss_fn(params, batch):
        return transformer.loss_fn(params, batch, sp, ctx)

    def pin_grads(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), tree,
            grad_shardings)

    def train_step(params, opt_state, batch, rng):
        b = batch["tokens"].shape[0]
        assert b % mb == 0, (b, mb)

        def reshape_mb(x):
            return x.reshape(mb, b // mb, *x.shape[1:])
        mbatch = jax.tree.map(reshape_mb, batch)

        def mb_step(acc, mbx):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mbx)
            grads = pin_grads(grads)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return pin_grads(acc), loss

        g0 = pin_grads(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params))
        grads, losses = jax.lax.scan(mb_step, g0, mbatch)
        grads = jax.tree.map(lambda g: g / mb, grads)
        if grad_compress:
            # int8-compressed DP all-reduce (params replicated over dp axes)
            grads = _compressed_dp_allreduce(grads, rng)
        updates, opt_state, om = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": jnp.mean(losses), **om}
        return params, opt_state, metrics

    return train_step


def _compressed_dp_allreduce(grads, rng):
    """Placeholder hook replaced under shard_map in make_compressed_train_step;
    in the pure-pjit path GSPMD already reduced grads, so identity."""
    return grads


def make_compressed_train_step(cfg: ArchConfig, sp, opt, mesh: Mesh, *,
                               microbatches: int | None = None,
                               ctx: ModelCtx | None = None):
    """Beyond-paper variant: manual DP via shard_map with int8-compressed
    gradient all-reduce; 'model' axis left to GSPMD (auto). Params must be
    replicated over dp axes (no FSDP) — used for small/mid models where the
    collective term is gradient-bound."""
    ctx = ctx or ModelCtx(mode="train")
    mb = microbatches or cfg.microbatches
    dp = dp_axes(mesh)

    def loss_fn(params, batch):
        return transformer.loss_fn(params, batch, sp, ctx)

    def body(params, opt_state, batch, rng):
        b = batch["tokens"].shape[0]
        def reshape_mb(x):
            return x.reshape(mb, max(b // mb, 1), *x.shape[1:])
        mbatch = jax.tree.map(reshape_mb, batch)

        def mb_step(acc, mbx):
            (_, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mbx)
            loss, _ = loss_fn(params, mbx)
            return jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads), loss

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, losses = jax.lax.scan(mb_step, g0, mbatch)
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        grads = compress.compressed_psum(grads, dp, rng)      # int8 wire format
        grads = jax.tree.map(lambda g: g / (mb * n_dp), grads)
        updates, opt_state, om = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": jnp.mean(losses), **om}

    return compress.shard_map(body, mesh=mesh,
                              in_specs=(P(), P(), P(tuple(dp)), P()),
                              out_specs=(P(), P(), P()),
                              check_vma=False)


def make_prefill_step(cfg: ArchConfig, sp, *, ctx: ModelCtx | None = None):
    """Serve prefill; every quantized matmul goes through
    kernels.dispatch.qgemm with a per-layer OperatingPoint — precisions from
    the layer's policy assignment, formulation/backend/tune from ctx.

    NOTE: under ctx.moe_stats the transformer entry points return a third
    MoE routing-stats value (the serve driver's contract). The default ctx
    here leaves it off, so these step builders — and the dry-run cells that
    lower them via `jax.eval_shape(step, ...)[1]` — keep the 2-tuple shape."""
    ctx = ctx or ModelCtx(mode="serve")

    def prefill_step(params, batch):
        return transformer.prefill(params, batch["tokens"], sp, ctx,
                                   frontend_embeds=batch.get("frontend"))
    return prefill_step


def make_decode_step(cfg: ArchConfig, sp, *, ctx: ModelCtx | None = None):
    """Serve decode. When `batch` carries a "pages" entry (a
    (slots, max_pages) int32 page table), the paged-pool layout is lowered —
    the same fixed decode signature the continuous-batching server jits, so
    dry-run cells cost the real thing. Reads go through the PageTable
    indirection, which is what makes prefix-shared pages transparent to the
    model; the WRITE side relies on the scheduler's fork-before-write
    contract (launch/serve.py `_prepare_pages`): by the time this step runs,
    every page a slot writes is exclusively owned.

    The paged READ path is selected by ctx (threaded from the serve driver's
    --backend/--paged-attn/--tune flags): backend "pallas" (or
    paged_attn="fused") lowers the fused page-walk kernel
    (kernels.paged_attn.paged_flash_decode, its pages-per-block Tile from
    ctx.tune or the shipped TuneTable) in place of the jnp gather — both
    paths share the identical cache write and post-fork table, so swapping
    them never changes the decode signature or the CoW contract.

    Multi-tenant serving (launch/multi_serve.py) builds one of these per
    tenant — the signature is keyed by that tenant's (cfg, policy, ctx), so
    co-scheduled models never share a trace and the per-model --jit-budget
    accounting stays exact even though every tenant's pages live in the one
    shared pool."""
    ctx = ctx or ModelCtx(mode="serve")

    def decode_step(params, batch):
        return transformer.decode_step(params, batch["cache"], batch["tokens"],
                                       batch["pos"], sp, ctx,
                                       pages=batch.get("pages"))
    return decode_step


def make_chunk_step(cfg: ArchConfig, sp, *, ctx: ModelCtx | None = None):
    """Serve chunked-prefill step (transformer.prefill_chunk): one prompt
    chunk scattered/attended through the paged pool at a position offset —
    the piece the mixed prefill/decode server tick dispatches alongside
    `make_decode_step` so long prompts stop stalling the decode slots.

    `batch` carries tokens (B, C), pos0 (B,), read_pages/write_pages
    (B, max_pages), nreal (B,) and last_idx (B,) — all fixed shapes for a
    given chunk budget C, so chunked traffic compiles exactly one extra
    signature next to the decode step (the serve driver's --jit-budget
    accounting counts it under the "chunk" key)."""
    ctx = ctx or ModelCtx(mode="serve")

    def chunk_step(params, batch):
        return transformer.prefill_chunk(
            params, batch["cache"], batch["tokens"], batch["pos0"], sp, ctx,
            read_pages=batch["read_pages"], write_pages=batch["write_pages"],
            nreal=batch["nreal"], last_idx=batch["last_idx"])
    return chunk_step


# ---------------------------------------------------------------------------
# shape/sharding assembly for a (cfg, workload shape, mesh) cell
# ---------------------------------------------------------------------------

def abstract_train_state(cfg: ArchConfig, opt):
    """(params, opt_state) as ShapeDtypeStructs — no allocation."""
    params = jax.eval_shape(lambda: transformer.init(jax.random.PRNGKey(0), cfg))
    opt_state = jax.eval_shape(lambda: opt.init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params)))
    return params, opt_state


def abstract_serve_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: transformer.pack_for_serve(
        transformer.init(jax.random.PRNGKey(0), cfg), cfg))


def act_dp_for(mesh: Mesh, per_step_batch: int) -> tuple | None:
    """dp axes to pin activations to, if they divide the batch."""
    dp = dp_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    return tuple(dp) if (n and per_step_batch % n == 0) else None


def cell_lowering_args(cfg: ArchConfig, shape: ShapeConfig | str, mesh: Mesh, *,
                       opt=None, fsdp: bool = True):
    """Everything jax.jit(...).lower(...) needs for one dry-run cell:
    (step_fn, arg ShapeDtypeStructs, in_shardings, out_shardings, donate)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    sp = transformer.build_specs(cfg)
    inputs = registry.input_specs(cfg, shape)
    mb = cfg.microbatches if shape.kind == "train" else 1
    ctx = ModelCtx(mode="train" if shape.kind == "train" else "serve",
                   act_dp=act_dp_for(mesh, shape.global_batch // mb),
                   attn_cp="model" if shape.seq_len % mesh.shape["model"] == 0
                   else None,
                   fsdp_wire=cfg.fsdp_wire)

    if shape.kind == "train":
        opt = opt or make_optimizer(cfg)
        params, opt_state = abstract_train_state(cfg, opt)
        ps = sharding.param_shardings(mesh, params, fsdp=fsdp)
        step = make_train_step(cfg, sp, opt, ctx=ctx, grad_shardings=ps)
        os_ = sharding.opt_state_shardings(mesh, opt_state, ps)
        bs = sharding.batch_shardings(mesh, inputs, global_batch=shape.global_batch)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        args = (params, opt_state, inputs, rng)
        in_sh = (ps, os_, bs, NamedSharding(mesh, P()))
        out_sh = (ps, os_, None)
        return step, args, in_sh, out_sh, (0, 1)

    params = abstract_serve_params(cfg)
    # serve weights: TP over model, REPLICATED over dp — packed ternary/binary
    # weights are 8-32x smaller than bf16 (the BrainTTA point), so they fit
    # replicated; FSDP gathers per decoded token would drown the memory term.
    ps = sharding.param_shardings(mesh, params, fsdp=False)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, sp, ctx=ctx)
        bs = sharding.batch_shardings(mesh, inputs, global_batch=shape.global_batch)
        with mesh:   # shard_act constraints need the mesh context to trace
            out_cache = jax.eval_shape(step, params, inputs)[1]
        cache_out_sh = sharding.cache_shardings(mesh, out_cache,
                                                batch=shape.global_batch)
        return step, (params, inputs), (ps, bs), (None, cache_out_sh), ()
    # decode
    step = make_decode_step(cfg, sp, ctx=ctx)
    cache_sh = sharding.cache_shardings(mesh, inputs["cache"],
                                        batch=shape.global_batch)
    tok_sh = sharding.batch_shardings(
        mesh, {k: v for k, v in inputs.items() if k != "cache"},
        global_batch=shape.global_batch)
    bs = {**tok_sh, "cache": cache_sh}
    return step, (params, inputs), (ps, bs), (None, cache_sh), (1,)