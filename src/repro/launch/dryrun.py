import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh) cell
against 512 placeholder CPU devices, prove the sharding is coherent, and
extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k [--multi-pod] [--out out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/

The XLA_FLAGS line above MUST precede any jax import (jax locks the device
count on first init) — and must NOT leak into tests/benches, which see one
device (hence: only here, never in conftest).
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.configs.base import SHAPES        # noqa: E402
from repro.launch import roofline as rl      # noqa: E402
from repro.launch import step as step_mod    # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def calibrate_cost_scope(mesh) -> str:
    """Determine whether compiled.cost_analysis() reports per-device or global
    FLOPs under SPMD partitioning, by lowering a known matmul."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = 1024
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    sh = NamedSharding(mesh, P("data", "model"))
    c = (jax.jit(lambda a, b: a @ b, in_shardings=(sh, sh))
         .lower(x, x).compile())
    cost = c.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0))
    global_flops = 2 * n ** 3
    return "global" if flops > 0.5 * global_flops else "per_device"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, cost_scope: str,
             verbose: bool = True, fsdp: bool = True, overrides: dict | None = None):
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    chips = mesh.size
    t0 = time.time()
    step, args, in_sh, out_sh, donate = step_mod.cell_lowering_args(
        cfg, shape_name, mesh, fsdp=fsdp)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    r = rl.analyse(arch, shape_name, mesh_name, chips, compiled, cfg,
                   cost_scope=cost_scope)
    out = r.to_json()
    out["lower_s"] = round(t_lower, 1)
    out["compile_s"] = round(t_compile, 1)
    out["policy"] = cfg.policy
    if verbose:
        ma = out["memory_per_device"]
        print(f"[{arch} × {shape_name} × {mesh_name}] OK "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print(f"  memory/device: args {ma.get('argument_size_in_bytes', 0)/2**30:.2f} GiB, "
              f"temp {ma.get('temp_size_in_bytes', 0)/2**30:.2f} GiB, "
              f"out {ma.get('output_size_in_bytes', 0)/2**30:.2f} GiB "
              f"(alias {ma.get('alias_size_in_bytes', 0)/2**30:.2f})")
        print(f"  roofline[s]: compute {r.t_compute:.4f}  memory {r.t_memory:.4f} "
              f" collective {r.t_collective:.4f}  -> {r.bottleneck}-bound, "
              f"useful-ratio {r.useful_ratio:.2f}, roofline-frac {r.roofline_fraction:.3f}")
        pk = {k: round(v / 2**20, 1) for k, v in out['coll_detail']['per_kind'].items() if v}
        print(f"  collectives (MiB): {pk}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every supported (arch, shape) cell")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--policy", default=None, help="override precision policy")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (bool/int/str inferred)")
    ap.add_argument("--out", default=None, help="JSON output file")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    assert mesh.size == (512 if args.multi_pod else 256), mesh
    cost_scope = calibrate_cost_scope(mesh)
    print(f"devices: {len(jax.devices())}, mesh {dict(mesh.shape)}, "
          f"cost_analysis scope: {cost_scope}")

    overrides = {}
    if args.policy:
        overrides["policy"] = args.policy
    if args.microbatches:
        overrides["microbatches"] = args.microbatches
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("true", "false"):
            v = v == "true"
        elif v.lstrip("-").isdigit():
            v = int(v)
        overrides[k] = v

    cells = []
    if args.all:
        for a in ARCHS:
            for s in get_config(a).supported_shapes:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results, failures = [], []
    for a, s in cells:
        try:
            results.append(run_cell(a, s, multi_pod=args.multi_pod,
                                    cost_scope=cost_scope,
                                    fsdp=not args.no_fsdp,
                                    overrides=overrides or None))
        except Exception as e:  # a failing cell is a bug — surface loudly
            traceback.print_exc()
            failures.append({"arch": a, "shape": s, "error": repr(e)})
    if args.out:
        payload = {"multi_pod": args.multi_pod, "cost_scope": cost_scope,
                   "results": results, "failures": failures}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.out}")
    print(f"{len(results)} cells OK, {len(failures)} failed")
    if failures:
        for f in failures:
            print("FAILED:", f["arch"], f["shape"], f["error"][:200])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
