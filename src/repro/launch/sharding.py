"""Sharding rules: param/cache/input PartitionSpecs from pytree paths.

Strategy (DESIGN.md §3):
  * TP over "model": column-parallel for qkv/up projections (out-dim), row-
    parallel for out/down projections (in-dim) — Megatron pairing, so one
    collective per block instead of two.
  * FSDP over "data": the non-TP weight dim is sharded over the data axis
    (ZeRO-3 via GSPMD; gathered per-layer under the scan).
  * EP over "model" for MoE expert stacks (leading E axis). Under serve
    (fsdp=False) this placement is exploited by compute: kernels.dispatch
    runs the grouped expert dispatch (`_ep_column`/`_ep_row`) whose
    shard_map in_specs are exactly these rules — each shard computes only
    its local experts. `ep_plan`'s whole-expert guard (E % model == 0) and
    `fit_spec`'s drop of non-dividing axes agree by construction: a config
    whose expert count the axis can't split replicates the stack here AND
    falls back to the dense expert vmap there (docs/MOE.md).
  * "pod" axis: pure DP — parameters are NOT sharded over pods (gathering
    weights over DCI every layer would drown; gradients all-reduce over pod
    instead).
  * int8 optimizer moments (shape-preserving codec) shard exactly like their
    parameter; per-block scales drop the sharded last-axis spec if blocking
    collapsed it.
  * batch-bearing tensors (inputs, caches, activations) shard batch over
    ("data",) [+"pod"], heads over "model" where present; batch=1 long-context
    decode falls back to replicated batch + model-sharded heads/state.

Everything is a *rule on the leaf path + shape*, applied with
jax.tree_util.tree_map_with_path — transparent, testable, no model changes.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import pack
from .mesh import dp_axes

def abstract_mesh(shape=(16, 16), axes=("data", "model")):
    """An AbstractMesh for rule evaluation — no devices needed.

    jax >= 0.4.36 constructs AbstractMesh from a ((name, size), ...) shape
    tuple; older releases took (sizes, names) positionally. Accept the
    legacy (sizes, names) call shape here and translate."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:   # pre-0.4.36 signature
        return AbstractMesh(tuple(shape), tuple(axes))


# path components that mark a row-parallel linear (contraction dim sharded)
_ROW_PARALLEL = {"out", "down"}
# leaf names of packed weight tensors (K packed along the last axis; the
# per-leaf pack factor — 32-operand bit-plane words, 8-nibble s4 words —
# lives in core.pack.K_QUANTUM, shared with kernels.dispatch.tp_plan)
_PACKED = frozenset(pack.K_QUANTUM)


def _names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return out


def _is_row_parallel(names: list[str]) -> bool:
    return any(n in _ROW_PARALLEL for n in names)


def param_spec(path, leaf, *, fsdp: bool = True, scanned_ok: bool = True) -> P:
    """PartitionSpec for one parameter leaf."""
    names = _names(path)
    shape = leaf.shape
    ndim = len(shape)
    name = names[-1] if names else ""
    fs = "data" if fsdp else None

    # scanned 'mid' stacks carry a leading period axis -> spec gets None front
    lead: tuple = ()
    if "mid" in names and scanned_ok:
        lead, shape, ndim = (None,), shape[1:], ndim - 1

    def out(*dims):
        return P(*lead, *dims)

    if "embed" in names:                       # (V, D): vocab over model only
        # (sharding D over data caused involuntary full-remat gathers in SPMD)
        return out("model", None)
    if name == "rec":                          # sLSTM (H, dh, 4dh): heads
        return out("model", None, None)
    if name in ("scale", "bias", "lam"):       # norms / Lambda: replicate
        return out(*([None] * ndim))
    if name == "w_gates":                      # (Dr, 2)
        return out(fs, None)
    if names and "conv" in names:              # depthwise conv (width, D)/(D,)
        return out(*( [None] * (ndim - 1) + [ "model" ] )) if ndim else P()

    row = _is_row_parallel(names)
    is_expert = ("ffn" in names and ndim == 3 and name in
                 ("w", "w_q") or (name in _PACKED and ndim == 3) or
                 (name == "w_scale" and ndim == 2) or (name == "b" and ndim == 2))

    if name == "w_planes":                     # ((E,) bits, out, K/32) stack
        # NOT the generic packed rule: the leading plane axis makes the
        # non-expert leaf 3D, which the `is_expert` heuristic below would
        # misread as an expert stack. Planes replicate (they are facets of
        # ONE logical weight); out/K shard exactly like the 2D packed leaves.
        if ndim == 4:                          # expert stack (E, b, out, K/32)
            return out("model", None, None, fs) if not row \
                else out("model", None, fs, None)
        return out(None, fs, "model") if row else out(None, "model", fs)
    if name == "w" or name == "w_q":           # dense (in, out) train/int8
        if is_expert:                          # (E, in, out): EP + FSDP
            return out("model", fs, None) if not row else out("model", None, fs)
        if "router" in names:
            return out(fs, None)               # (D, E): tiny, replicate E
        return out("model", fs) if row else out(fs, "model")
    if name in _PACKED:                        # (out, K/32) packed planes
        if is_expert:
            return out("model", None, fs) if not row else out("model", fs, None)
        return out(fs, "model") if row else out("model", fs)
    if name == "w_scale":                      # (out,)
        if is_expert:
            return out("model", None)
        return out(None) if row else out("model")
    if name == "b":                            # bias (out,)
        if is_expert:
            return out("model", None)
        return out(None) if row else out("model")
    if name == "a_scale":
        return P()
    # anything else small: replicate
    return out(*([None] * ndim))


def cache_spec(path, leaf, *, batch_shardable: bool) -> P:
    """PartitionSpec for a KV-cache / recurrent-state leaf."""
    names = _names(path)
    name = names[-1] if names else ""
    shape = leaf.shape
    lead: tuple = ()
    if "mid" in names:
        lead, shape = (None,), shape[1:]
    bdim = ("data",) if batch_shardable else None  # pod handled by caller remap

    def out(*dims):
        return P(*lead, *dims)

    if name in ("k", "v", "cross_k", "cross_v"):   # (B, S, Hk, dh)
        # cache sequence sharded over model (kv-head counts — 8/4/1 — don't
        # divide a 16-way axis; decode attention psums over the seq shards)
        return out(bdim, "model", None, None)
    if name == "C":                                 # (B, H, dk, dv)
        return out(bdim, "model", None, None)
    if name in ("n",):                              # (B, H, dk) or (B, D)
        return out(bdim, "model", None) if len(shape) == 3 else out(bdim, "model")
    if name == "m":                                 # (B, H) or (B, D)
        return out(bdim, *( [None] * (len(shape) - 1) ))
    if name in ("c", "h"):                          # (B, D)
        return out(bdim, "model")
    if name == "conv":                              # (B, w-1, D)
        return out(bdim, None, "model")
    return out(bdim, *([None] * (len(shape) - 1)))


def _widen_dp(spec: P, mesh: Mesh) -> P:
    """Replace 'data' with ('pod','data') on multi-pod meshes for batch dims
    of *data* tensors (params stay un-sharded over pod)."""
    if "pod" not in mesh.axis_names:
        return spec
    return P(*[("pod", "data") if d == ("data",) or d == "data" else d
               for d in spec])


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on any dim the mesh axes don't divide exactly (explicit
    pjit in_shardings require divisibility, unlike GSPMD-internal padding)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for size, d in zip(shape, dims):
        if d is None:
            out.append(None)
            continue
        axes = d if isinstance(d, tuple) else (d,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(d if size % n == 0 else None)
    return P(*out)


def _guard_packed_k(spec: P, path, leaf, mesh) -> P:
    """Packed-weight guard: the serve rules shard the *packed* last axis of
    `w_packed`/`w_mask`/`w_sign` (K/32-bit words). A shard boundary must
    never fall inside a packed word, so the axis is only shardable when each
    shard keeps a whole number of words — i.e. the unpacked K divides
    pack_factor(32) x shard_count; a non-dividing packed K falls back to
    replicated instead of a mid-word split.

    Today `fit_spec`'s generic element-count check happens to drop the same
    axes (the packed dim IS counted in words), so this guard exists for two
    other reasons: it names the whole-word invariant explicitly, and it
    routes through `core.pack.shardable_words` — the exact predicate
    `kernels.dispatch.tp_plan` uses — so if `fit_spec` is ever relaxed
    (e.g. to allow GSPMD's padded uneven sharding), packed leaves still
    refuse mid-word splits and the device layout can never disagree with
    the shard_map compute."""
    names = _names(path)
    if not names or names[-1] not in _PACKED:
        return spec
    dims = list(spec) + [None] * (leaf.ndim - len(spec))
    d = dims[-1]
    if d is None:
        return spec
    axes = d if isinstance(d, tuple) else (d,)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    if not pack.shardable_words(leaf.shape[-1], n):
        dims[-1] = None
    return P(*dims)


def param_shardings(mesh: Mesh, param_tree, *, fsdp: bool = True):
    """NamedSharding tree for parameters (train or serve layout)."""
    def one(path, leaf):
        spec = _guard_packed_k(param_spec(path, leaf, fsdp=fsdp),
                               path, leaf, mesh)
        spec = fit_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, param_tree)


def opt_state_shardings(mesh: Mesh, opt_state, param_shardings_tree):
    """Optimizer state shards exactly like its parameter. The int8 moment
    codec is shape-preserving (codes: param shape; scales: param rank with
    last dim = n_blocks), so the param's PartitionSpec applies verbatim —
    the optimizer update is fully local, no resharding collectives."""
    from repro.optim.adamw import AdamWState, Q8Tensor

    def shard_like(ps, mleaf):
        if isinstance(mleaf, Q8Tensor):
            return Q8Tensor(codes=ps, scale=NamedSharding(
                mesh, fit_spec(ps.spec, mleaf.scale.shape, mesh)))
        return ps

    mk = lambda tree: jax.tree.map(
        shard_like, param_shardings_tree, tree,
        is_leaf=lambda x: isinstance(x, NamedSharding))
    return AdamWState(NamedSharding(mesh, P()),
                      mk(opt_state.m), mk(opt_state.v))


def batch_shardings(mesh: Mesh, batch_tree, *, global_batch: int):
    """Inputs: shard batch dim over all dp axes that divide it."""
    dp = [a for a in dp_axes(mesh)]
    size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    axes = tuple(dp) if global_batch % max(size, 1) == 0 else ("data",) \
        if global_batch % mesh.shape.get("data", 1) == 0 else ()

    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = P(axes if axes else None, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def serve_cache_shardings(mesh: Mesh, cache_tree):
    """NamedSharding tree for the continuous-batching server's cache.

    Every leaf shards its leading content axis over "data": for paged pool
    leaves (num_pages, page_size, Hk, dh) that is the *page* axis — whole
    pages per shard, so a page's tokens stay device-local and the decode
    gather/scatter through the page table is exact — and for slab leaves
    (window rings, recurrent state, cross-KV) it is the *slot* axis — whole
    requests per shard. The host PageTable (admission, free list) stays
    global numpy; scanned mid-stack leaves carry a leading (n_periods,) dim
    that stays unsharded. Axes the mesh does not divide fall back to
    replicated (fit_spec), e.g. the default pool of slots*max_pages+1 pages
    (the +1 scratch page makes it odd).

    Scheduler state is deliberately OUTSIDE these rules: page refcounts, the
    prefix-share hash index, the free list and preemption swap slabs are all
    host-side numpy (see launch/kv_cache.py) — spilled capacity and
    allocator metadata, not working set, so they never occupy device memory
    or enter a jitted signature. Prefix sharing and copy-on-write only remap
    *which* page ids appear in the (host) table; the device placement rules
    above are unchanged by them — re-verified token-exact under `--mesh` by
    tests/test_serving_sched.py. The same holds for the tiered prefix cache
    (launch/cache_tiers.py) and multi-tenant SlotView windows
    (launch/multi_serve.py): parked pages, host/disk slabs and per-tenant
    slot ranges are all host bookkeeping over the one shared pool, so they
    inherit these rules unmodified.
    """
    def one(path, leaf):
        names = _names(path)
        lead = 1 if "mid" in names else 0
        dims = [None] * leaf.ndim
        if leaf.ndim > lead and "data" in mesh.axis_names:
            dims[lead] = "data"        # page axis (pool) or slot axis (slab)
        return NamedSharding(mesh, fit_spec(P(*dims), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def repin_serve_cache(mesh: Mesh, cache_tree):
    """Re-apply the serve cache placement after a host-driven update.

    Swap-in scatters a preempted request's host slab back into the pool with
    eager `.at[ids].set` ops, and tier promotion (launch/cache_tiers.py)
    scatters a host/disk slab image the same way; outside jit, sharding
    propagation through such an update is backend-dependent, so the server
    re-pins the result to the canonical `serve_cache_shardings` layout (a
    no-op device_put when the placement already matches). Keeping this here
    — next to the rules it re-applies — means serve.py cannot drift from the
    layout contract."""
    return jax.device_put(cache_tree, serve_cache_shardings(mesh, cache_tree))


def cache_shardings(mesh: Mesh, cache_tree, *, batch: int):
    dp = list(dp_axes(mesh))
    size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    shardable = batch % max(size, 1) == 0

    def one(path, leaf):
        spec = cache_spec(path, leaf, batch_shardable=shardable)
        # widen batch to include pod axis
        dims = list(spec)
        if shardable and dims and dims[0] == ("data",):
            dims[0] = tuple(dp)
        elif dims and isinstance(dims[0], tuple) and "mid" not in _names(path):
            pass
        if "mid" in _names(path) and shardable and len(dims) > 1 and dims[1] == ("data",):
            dims[1] = tuple(dp)
        # singleton axis tuples are NOT equal to the bare name in PartitionSpec
        dims = [d[0] if isinstance(d, tuple) and len(d) == 1 else d for d in dims]
        return NamedSharding(mesh, fit_spec(P(*dims), leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_tree)
