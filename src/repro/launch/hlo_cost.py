"""Trip-count-aware HLO cost model.

`compiled.cost_analysis()` counts a `while` (lax.scan) body ONCE, ignoring the
trip count — useless for scan-over-layers/microbatch models (verified: an
8-step scanned matmul reports 1/8 the FLOPs of its unrolled twin). This module
parses the post-SPMD optimized HLO text and computes:

    flops             dot ops: 2 * result_elems * contracted_elems
                      (elementwise ops: 1 flop/result element, XLA convention)
    bytes             per top-level op: operands + result at fusion boundaries
                      (dynamic-slice/update-slice count sliced bytes only —
                      the in-place KV-cache update costs its update, not the
                      whole cache)
    collective bytes  result bytes of all-reduce / all-gather / reduce-scatter
                      / all-to-all / collective-permute, by kind

with every op's cost multiplied by the product of enclosing while-loop trip
counts (canonical scan conditions: `compare(counter, constant(N))`).

This is the project's dry-run profiler: §Roofline and §Perf read from it.
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[a-z0-9\[\],{} ]+?))\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


@dataclasses.dataclass
class Shape:
    parts: list[tuple[str, tuple[int, ...]]]

    @property
    def bytes(self) -> int:
        total = 0
        for dt, dims in self.parts:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES.get(dt, 4)
        return total

    @property
    def elems(self) -> int:
        return sum(int(__import__("numpy").prod(d)) if d else 1
                   for _, d in self.parts)


def _parse_shape(text: str) -> Shape:
    parts = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        if dt in _DTYPE_BYTES or dt in ("s4", "u4"):
            dims_t = tuple(int(x) for x in dims.split(",") if x)
            parts.append((dt, dims_t))
    return Shape(parts)


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    shape: Shape
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict  # name -> Op
    order: list


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    coll_count: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


# ops that move no data / are free
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator"}
# pure data movement (bytes, no flops)
_MOVE = {"copy", "reshape", "transpose", "broadcast", "slice", "concatenate",
         "pad", "reverse", "convert"}


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1), {}, [])
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        om = _OPCODE_RE.match(rest)
        if not om:
            continue
        shape_txt, opcode = om.groups()
        # operand list: inside the first (...) after opcode
        paren = rest[om.end() - 1:]
        depth, end = 0, len(paren)
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(paren[:end + 1])
        cur.ops[name] = Op(name, opcode, _parse_shape(shape_txt), operands, rest)
        cur.order.append(name)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops.values():
        if op.opcode == "constant" or "constant(" in op.attrs:
            pass
    # scan constants in the raw attr text of all ops
    for op in cond.ops.values():
        for m in _CONST_RE.finditer(op.attrs):
            best = max(best, int(m.group(1)))
        if op.opcode == "constant":
            m = _CONST_RE.search(op.attrs) or None
    return best


def _dot_flops(op: Op, table: dict[str, Shape]) -> float:
    lhs = table.get(op.operands[0] if op.operands else "", None)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    result_elems = op.shape.elems
    if lhs is None or not lhs.parts or m is None:
        return 2.0 * result_elems  # fallback
    cdims = [int(x) for x in m.group(1).split(",") if x]
    contracted = 1
    for d in cdims:
        if d < len(lhs.parts[0][1]):
            contracted *= lhs.parts[0][1][d]
    return 2.0 * result_elems * contracted


def _fusion_bytes(comps, callee: str | None, op: "Op", table: dict) -> float:
    """Bytes moved at a fusion boundary: result + effective operand reads.

    An operand whose only consumers inside the fused computation are
    dynamic-slice / gather ops contributes the *slice* bytes, not the full
    array (critical under lax.scan: the stacked layer params and the
    microbatched batch are operands of every body fusion but only one slice
    is read per trip). The fused root being a dynamic-update-slice writes its
    update, not the whole (aliased) buffer.
    """
    result_bytes = op.shape.bytes
    if callee is None or callee not in comps:
        return result_bytes + sum(table[o].bytes for o in op.operands
                                  if o in table)
    comp = comps[callee]
    # map parameter index -> consumers
    param_ops = {}
    for name in comp.order:
        o = comp.ops[name]
        if o.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", o.attrs)
            if m:
                param_ops[int(m.group(1))] = name
    consumers: dict[str, list] = {}
    root = None
    for name in comp.order:
        o = comp.ops[name]
        if "ROOT" in o.attrs or name == comp.order[-1]:
            root = o
        for opd in o.operands:
            consumers.setdefault(opd, []).append(o)
    total = 0.0
    for i, opd in enumerate(op.operands):
        if opd not in table:
            continue
        full = table[opd].bytes
        pname = param_ops.get(i)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c.opcode in ("dynamic-slice", "gather") for c in cons):
            total += sum(c.shape.bytes for c in cons)
        else:
            total += full
    if root is not None and root.opcode == "dynamic-update-slice":
        # aliased in-place update: write the update, not the whole buffer
        upd_name = root.operands[1] if len(root.operands) > 1 else None
        upd = comp.ops.get(upd_name)
        result_bytes = (upd.shape.bytes if upd is not None else result_bytes)
    return result_bytes + total


def _comp_cost(comps, cname: str, memo: dict, *, top_level: bool,
               fusion_ctx: bool = False) -> Cost:
    key = (cname, top_level, fusion_ctx)
    if key in memo:
        return memo[key]
    comp = comps[cname]
    total = Cost()
    table = {name: op.shape for name, op in comp.ops.items()}

    for name in comp.order:
        op = comp.ops[name]
        oc = op.opcode
        c = Cost()
        if oc in _FREE:
            pass
        elif oc == "while":
            body = _BODY_RE.search(op.attrs)
            cond = _COND_RE.search(op.attrs)
            trips = _trip_count(comps[cond.group(1)]) if cond else 1
            if body:
                c.add(_comp_cost(comps, body.group(1), memo, top_level=True),
                      mult=trips)
        elif oc == "fusion":
            callee = _CALLS_RE.search(op.attrs)
            cname_in = callee.group(1) if callee else None
            if cname_in:
                inner = _comp_cost(comps, cname_in, memo,
                                   top_level=False, fusion_ctx=True)
                c.flops += inner.flops
                c.add(Cost(coll=dict(inner.coll), coll_count=dict(inner.coll_count)))
            # bytes at the fusion boundary — but an operand consumed only via
            # dynamic-slice/gather inside the fusion is read sliced, not whole
            # (scan bodies slice one layer from the stacked params per trip!)
            c.bytes += _fusion_bytes(comps, cname_in, op, table)
        elif oc in ("call", "conditional", "custom-call", "async-start"):
            callee = _CALLS_RE.search(op.attrs)
            if callee and callee.group(1) in comps:
                # the callee's own ops are costed; adding the call result on
                # top would double-count the root write (copy-bytes overcount)
                c.add(_comp_cost(comps, callee.group(1), memo, top_level=True))
            else:
                c.bytes += op.shape.bytes
        elif any(oc.startswith(k) for k in COLLECTIVES):
            kind = next(k for k in COLLECTIVES if oc.startswith(k))
            if not oc.endswith("-done"):           # async pairs: start only
                # wire bytes per device (ring algorithms):
                #   all-reduce      ~2x tensor   (reduce-scatter + all-gather)
                #   reduce-scatter  ~1x input    (result is 1/N of it)
                #   all-gather      ~1x result
                #   all-to-all / permute ~1x result
                if kind == "all-reduce":
                    wire = 2.0 * op.shape.bytes
                elif kind == "reduce-scatter":
                    ops_in = [table[o] for o in op.operands if o in table]
                    wire = float(sum(sh.bytes for sh in ops_in)) or op.shape.bytes
                else:
                    wire = float(op.shape.bytes)
                c.coll[kind] = c.coll.get(kind, 0.0) + wire
                c.coll_count[kind] = c.coll_count.get(kind, 0.0) + 1
                c.bytes += op.shape.bytes * 2
        elif oc == "dot":
            c.flops += _dot_flops(op, table)
            if top_level and not fusion_ctx:
                c.bytes += op.shape.bytes + sum(
                    table[o].bytes for o in op.operands if o in table)
        elif oc == "convolution":
            c.flops += 2.0 * op.shape.elems  # conservative (no conv in hot path)
            if top_level and not fusion_ctx:
                c.bytes += op.shape.bytes * 2
        elif oc in ("dynamic-slice", "gather"):
            c.bytes += op.shape.bytes * (2 if (top_level and not fusion_ctx) else 0)
        elif oc == "dynamic-update-slice":
            upd = (table[op.operands[1]].bytes
                   if len(op.operands) > 1 and op.operands[1] in table
                   else op.shape.bytes)
            c.bytes += 2 * upd if (top_level and not fusion_ctx) else 0
        elif oc == "scatter":
            c.bytes += op.shape.bytes * (2 if (top_level and not fusion_ctx) else 0)
        elif oc in _MOVE:
            if top_level and not fusion_ctx:
                c.bytes += op.shape.bytes + sum(
                    table[o].bytes for o in op.operands if o in table)
        elif oc in ("reduce", "reduce-window", "sort", "map", "select-and-scatter"):
            ins = sum(table[o].elems for o in op.operands if o in table)
            c.flops += float(ins)
            if top_level and not fusion_ctx:
                c.bytes += op.shape.bytes + sum(
                    table[o].bytes for o in op.operands if o in table)
        else:
            # elementwise & friends: 1 flop per result element
            c.flops += float(op.shape.elems)
            if top_level and not fusion_ctx:
                c.bytes += op.shape.bytes + sum(
                    table[o].bytes for o in op.operands if o in table)
        total.add(c)
    memo[key] = total
    return total


def analyze_text(hlo_text: str) -> Cost:
    comps = parse_module(hlo_text)
    if "__entry__" not in comps:
        # fall back: last computation is usually entry
        if not comps:
            return Cost()
        comps["__entry__"] = comps[list(comps)[-1]]
    memo: dict = {}
    return _comp_cost(comps, comps["__entry__"].name, memo, top_level=True)


def analyze_compiled(compiled) -> Cost:
    return analyze_text(compiled.as_text())
