"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ck [--resume]

Production behaviors, runnable at laptop scale:
  * step-indexed data pipeline -> exact resume of the stream position
  * periodic atomic checkpoints (params + opt state + step), retain-N
  * auto-resume from the latest complete checkpoint (--resume)
  * failure injection (--fail-at-step N) to exercise the restart path
  * straggler/step-time monitor (EWMA + spike log -> elastic.py policy)
  * optional int8-compressed gradient all-reduce (--grad-compress)

On a real pod this module runs once per host (jax.distributed.initialize);
the data pipeline shards by host_index and the mesh comes from
make_production_mesh(). Here it drives the same code on CPU devices.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, Prefetcher, make_source
from repro.models import transformer
from repro.models.common import ModelCtx
from repro.optim.adamw import adamw, cosine_schedule

from . import elastic, step as step_mod
from .mesh import make_host_mesh
from . import sharding


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (smoke) config — CPU-trainable")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--layers", type=int, default=None,
                    help="override layer count (reduced smoke configs have 2 "
                         "layers, so first/last overrides mask body policies)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a crash (tests restart)")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data", default=None, help="packed token file (else synthetic)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.policy:
        cfg = dataclasses.replace(cfg, policy=args.policy)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    cfg = dataclasses.replace(cfg, microbatches=args.microbatches)
    sp = transformer.build_specs(cfg)

    opt = adamw(cosine_schedule(args.lr, warmup=max(args.steps // 20, 5),
                                total=args.steps),
                int8_state=cfg.opt_state_int8)
    mesh = make_host_mesh()
    ctx = ModelCtx(mode="train")
    if args.grad_compress:
        train_step = step_mod.make_compressed_train_step(cfg, sp, opt, mesh, ctx=ctx)
        jit_step = jax.jit(train_step)
    else:
        train_step = step_mod.make_train_step(cfg, sp, opt, ctx=ctx)
        jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    pipe_cfg = PipelineConfig(vocab=cfg.vocab, seq_len=args.seq,
                              global_batch=args.batch)
    source = make_source(pipe_cfg, args.data)

    start_step = 0
    params = opt_state = None
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        start_step = ckpt.latest_step(args.ckpt_dir)
        like_p = jax.eval_shape(lambda: transformer.init(jax.random.PRNGKey(0), cfg))
        like_o = jax.eval_shape(lambda: opt.init(jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), like_p)))
        state, _ = ckpt.restore(args.ckpt_dir, {"params": like_p, "opt": like_o})
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start_step}")
    if params is None:
        params = transformer.init(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} policy={cfg.policy} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())} steps {start_step}->{args.steps}")

    monitor = elastic.StepMonitor()
    prefetch = Prefetcher(source, start_step=start_step)
    rng = jax.random.PRNGKey(42)
    losses = []
    try:
        for _ in range(start_step, args.steps):
            step_i, host_batch = prefetch.next()
            batch = jax.tree.map(jnp.asarray, host_batch)
            t0 = time.time()
            rng, sub = jax.random.split(rng)
            params, opt_state, metrics = jit_step(params, opt_state, batch, sub)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            verdict = monitor.record(step_i, dt)
            losses.append(loss)
            if args.fail_at_step is not None and step_i == args.fail_at_step:
                raise RuntimeError(f"injected failure at step {step_i}")
            if step_i % args.log_every == 0:
                print(f"step {step_i:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                      + (f" [{verdict}]" if verdict else ""))
            if (args.ckpt_dir and step_i > start_step
                    and (step_i + 1) % args.ckpt_every == 0):
                ckpt.save(args.ckpt_dir, step_i + 1,
                          {"params": params, "opt": opt_state},
                          mesh_shape=tuple(mesh.devices.shape),
                          extra={"arch": cfg.name, "loss": loss})
    finally:
        prefetch.close()
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt_state},
                  mesh_shape=tuple(mesh.devices.shape),
                  extra={"arch": cfg.name, "loss": losses[-1] if losses else None})
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})"
          if losses else "no steps run")
    return losses


if __name__ == "__main__":
    main()
