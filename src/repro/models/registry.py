"""Model registry: config name -> (specs, init, step functions, input specs).

`input_specs(cfg, shape)` returns the ShapeDtypeStruct stand-ins for every
model input of a given workload shape — the dry-run lowers against these
(weak-type-correct, shardable, no device allocation). Modality frontends are
stubs per the assignment: the specs *are* the precomputed embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ArchConfig, SHAPES, ShapeConfig

from . import transformer
from .common import ModelCtx, TRAIN


def build(name_or_cfg) -> tuple[ArchConfig, transformer.ModelSpecs]:
    cfg = name_or_cfg if isinstance(name_or_cfg, ArchConfig) else get_config(name_or_cfg)
    return cfg, transformer.build_specs(cfg)


def build_serve_entry(arch: str, *, policy: str | None = None,
                      reduced: bool = False, backend: str = "jnp",
                      impl: str = "popcount", plane_twins: bool = False,
                      dtype=None, seed: int = 0
                      ) -> tuple[ArchConfig, dict, ModelCtx]:
    """One registry entry of the multi-tenant server: resolve an (arch,
    policy) pair to `(cfg, packed serve params, serve ModelCtx)`. Each
    tenant gets its own packed weight set and its own ctx — per-layer
    OperatingPoints resolve per model (`models.common.operating_point`), so
    heterogeneous precision policies coexist on one device."""
    import dataclasses

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if policy:
        cfg = dataclasses.replace(cfg, policy=policy)
    params = transformer.init(jax.random.PRNGKey(seed), cfg)
    packed = transformer.pack_for_serve(params, cfg,
                                        plane_twins=plane_twins
                                        or impl == "planes")
    ctx = ModelCtx(mode="serve", backend=backend, impl=impl)
    if dtype is not None:
        ctx = dataclasses.replace(ctx, dtype=dtype)
    return cfg, packed, ctx


def input_specs(cfg: ArchConfig, shape: ShapeConfig | str) -> dict:
    """ShapeDtypeStruct tree for the inputs of (arch x workload-shape)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, t = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    sd = jax.ShapeDtypeStruct

    def frontend():
        if cfg.frontend == "none":
            return {}
        return {"frontend": sd((b, cfg.frontend_len, cfg.d_model), bf16)}

    if shape.kind == "train":
        return {"tokens": sd((b, t), i32), "targets": sd((b, t), i32), **frontend()}
    if shape.kind == "prefill":
        return {"tokens": sd((b, t), i32), **frontend()}
    # decode: one new token against a cache of length t
    return {
        "tokens": sd((b, 1), i32),
        "pos": sd((), i32),
        "cache": transformer.cache_shapes(cfg, b, t),
    }


def make_batch(rng, cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Concrete random batch (smoke tests / CPU training)."""
    k1, k2, k3 = jax.random.split(rng, 3)
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab, jnp.int32),
           "targets": jax.random.randint(k2, (batch, seq), 0, cfg.vocab, jnp.int32)}
    if cfg.frontend != "none":
        out["frontend"] = (jax.random.normal(k3, (batch, cfg.frontend_len, cfg.d_model))
                           * 0.02).astype(jnp.bfloat16)
    return out
