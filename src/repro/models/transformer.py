"""Model assembly: decoder-only LMs (dense/MoE/SSM/hybrid/VLM) and the
whisper encoder-decoder, from the block library.

Structure (DESIGN.md §3): layer 0 and layer n-1 are *unrolled* and get the
policy's first/last precision (the paper's mixed-precision recipe); the
middle layers are scanned in whole block-pattern periods (`lax.scan` over
stacked params — the compile-time analogue of BrainTTA's hardware loop
buffer), any remainder layers are unrolled.

Each block is pre-norm residual: x += mixer(norm(x)); x += ffn(norm(x)).
Mixer kinds: attn | local (sliding-window) | slstm | mlstm | rglru.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import qlinear
from repro.core.precision import get_policy

from . import attention, common, ffn, moe, rglru, ssm
from .common import ModelCtx

# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockSpecs:
    kind: str
    mixer: Any
    ffn: Any = None        # FFNSpecs | MoESpecs | None
    is_moe: bool = False
    cross: bool = False    # whisper decoder block


def block_specs(cfg: ArchConfig, pol, kind: str, *, first=False, last=False,
                cross=False) -> BlockSpecs:
    if kind in ("attn", "local"):
        mix = attention.attn_specs(cfg, pol, first=first, last=last, cross=cross)
    elif kind == "mlstm":
        mix = ssm.mlstm_specs(cfg, pol, first=first, last=last)
    elif kind == "slstm":
        mix = ssm.slstm_specs(cfg, pol, first=first, last=last)
    elif kind == "rglru":
        mix = rglru.rglru_specs(cfg, pol, first=first, last=last)
    else:
        raise ValueError(kind)
    f = None
    is_moe = False
    if kind in ("attn", "local", "rglru") and cfg.d_ff > 0:
        if cfg.n_experts:
            f = moe.moe_specs(cfg, pol, first=first, last=last)
            is_moe = True
        else:
            f = ffn.ffn_specs(cfg, pol, first=first, last=last)
    return BlockSpecs(kind, mix, f, is_moe, cross)


def block_init(rng, cfg: ArchConfig, bs: BlockSpecs, dtype):
    ks = jax.random.split(rng, 4)
    p: dict[str, Any] = {"norm1": common.norm_init(cfg.d_model, cfg.norm, dtype)}
    if bs.kind in ("attn", "local"):
        p["mixer"] = attention.attn_init(ks[0], cfg, bs.mixer, dtype)
    elif bs.kind == "mlstm":
        p["mixer"] = ssm.mlstm_init(ks[0], cfg, bs.mixer, dtype)
    elif bs.kind == "slstm":
        p["mixer"] = ssm.slstm_init(ks[0], cfg, bs.mixer, dtype)
    elif bs.kind == "rglru":
        p["mixer"] = rglru.rglru_init(ks[0], cfg, bs.mixer, dtype)
    if bs.cross:
        p["norm_cross"] = common.norm_init(cfg.d_model, cfg.norm, dtype)
    if bs.ffn is not None:
        p["norm2"] = common.norm_init(cfg.d_model, cfg.norm, dtype)
        p["ffn"] = (moe.moe_init(ks[1], bs.ffn, dtype) if bs.is_moe
                    else ffn.ffn_init(ks[1], bs.ffn, dtype))
    return p


def _mixer_window(cfg: ArchConfig, kind: str) -> int:
    return cfg.window if kind == "local" else 0


def block_apply(p, x, bs: BlockSpecs, cfg: ArchConfig, ctx: ModelCtx, *,
                enc_out=None, causal=True):
    """Train/prefill-without-cache path. Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    h = common.norm_apply(p["norm1"], x, cfg.norm)
    if bs.kind in ("attn", "local"):
        m = attention.attn_apply(p["mixer"], h, bs.mixer, cfg, ctx, causal=causal,
                                 window=_mixer_window(cfg, bs.kind))
    elif bs.kind == "mlstm":
        m = ssm.mlstm_apply(p["mixer"], h, bs.mixer, ctx, impl=cfg.mlstm_impl)
    elif bs.kind == "slstm":
        m = ssm.slstm_apply(p["mixer"], h, bs.mixer, ctx)
    else:
        m = rglru.rglru_apply(p["mixer"], h, bs.mixer, ctx)
    x = x + m
    if bs.cross and enc_out is not None:
        k, v = attention.cross_kv(p["mixer"], enc_out, bs.mixer, cfg, ctx)
        hc = common.norm_apply(p["norm_cross"], x, cfg.norm)
        x = x + attention.cross_attn_apply(p["mixer"], hc, (k, v), bs.mixer, cfg, ctx)
    if bs.ffn is not None:
        h2 = common.norm_apply(p["norm2"], x, cfg.norm)
        if bs.is_moe:
            y, a = moe.moe_apply(p["ffn"], h2, bs.ffn, ctx)
            aux = aux + a["loss"]
        else:
            y = ffn.ffn_apply(p["ffn"], h2, bs.ffn, ctx)
        x = x + y
    return x, aux


def _moe_ffn(p, x, bs: BlockSpecs, cfg: ArchConfig, ctx: ModelCtx):
    """Shared serve-path FFN tail: residual add + optional MoE stats.

    Returns (x, st) where st is the block's routing-stat dict
    ({"expert_tokens": (E,) i32, "dropped": i32}) iff this is an MoE block
    and ctx.moe_stats is on, else None — the top-level entry points sum the
    dicts across blocks (None is the empty contribution, so dense blocks in
    a mixed pattern keep the scan carry structure constant)."""
    st = None
    if bs.ffn is None:
        return x, st
    h2 = common.norm_apply(p["norm2"], x, cfg.norm)
    if bs.is_moe:
        y, a = moe.moe_apply(p["ffn"], h2, bs.ffn, ctx)
        if ctx.moe_stats:
            st = {"expert_tokens": a["expert_tokens"], "dropped": a["dropped"]}
    else:
        y = ffn.ffn_apply(p["ffn"], h2, bs.ffn, ctx)
    return x + y, st


def _moe_zero(cfg: ArchConfig):
    """Zero routing-stat accumulator — the scan-carry seed when stats are on."""
    return {"expert_tokens": jnp.zeros((cfg.n_experts,), jnp.int32),
            "dropped": jnp.int32(0)}


def _moe_add(tot, st):
    if tot is None or st is None:
        return tot
    return jax.tree.map(lambda a, b: a + b, tot, st)


def block_cache_shapes(cfg: ArchConfig, bs: BlockSpecs, batch: int, seq_len: int,
                       paged: tuple[int, int] | None = None, kv_dtype=None):
    if bs.kind in ("attn", "local"):
        c = attention.init_cache_shapes(cfg, batch, seq_len,
                                        _mixer_window(cfg, bs.kind),
                                        dtype=kv_dtype, paged=paged)
    elif bs.kind == "mlstm":
        c = ssm.mlstm_state_shapes(cfg, batch)
    elif bs.kind == "slstm":
        c = ssm.slstm_state_shapes(cfg, batch)
    else:
        c = rglru.rglru_state_shapes(cfg, batch)
    if bs.cross:
        hk, dh = cfg.n_kv_heads, cfg.head_dim
        shp = (batch, cfg.frontend_len, hk, dh)
        c["cross_k"] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
        c["cross_v"] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
    return c


def block_prefill(p, x, bs: BlockSpecs, cfg: ArchConfig, ctx: ModelCtx, *,
                  enc_out=None, cache_len: int = 0):
    """Prefill: like block_apply but returns the decode cache.

    Returns (x, cache, st) — st per `_moe_ffn` (None unless an MoE block
    under ctx.moe_stats)."""
    h = common.norm_apply(p["norm1"], x, cfg.norm)
    cache = {}
    if bs.kind in ("attn", "local"):
        m, cache = attention.attn_apply(
            p["mixer"], h, bs.mixer, cfg, ctx, causal=True,
            window=_mixer_window(cfg, bs.kind), return_cache=True,
            cache_len=cache_len)
        x = x + m
    else:
        # recurrent mixers: run full sequence then recompute final state via
        # one-step decode chain is wasteful; instead run the scan and capture
        # the final state by replaying decode on the last token only after
        # processing prefix — implemented as scan-with-final-state below.
        x_new, cache = _recurrent_prefill(p["mixer"], h, bs, cfg, ctx)
        x = x + x_new
    if bs.cross and enc_out is not None:
        k, v = attention.cross_kv(p["mixer"], enc_out, bs.mixer, cfg, ctx)
        cache["cross_k"], cache["cross_v"] = k, v
        hc = common.norm_apply(p["norm_cross"], x, cfg.norm)
        x = x + attention.cross_attn_apply(p["mixer"], hc, (k, v), bs.mixer, cfg, ctx)
    x, st = _moe_ffn(p, x, bs, cfg, ctx)
    return x, cache, st


def _recurrent_prefill(pm, h, bs: BlockSpecs, cfg: ArchConfig, ctx: ModelCtx):
    """Run a recurrent mixer over the prefix and also return its final state.

    Baseline implementation: step the decode cell over time with lax.scan —
    sequential but state-exact. (rglru's parallel apply is used for train;
    prefill needs the state, so we scan the cell.)
    """
    b, t, _ = h.shape
    if bs.kind == "rglru" and not cfg.seq_prefill:
        # parallel prefill (§Perf A): associative scan + direct state extract
        return rglru.rglru_prefill(pm, h, bs.mixer, ctx)
    if bs.kind == "mlstm" and not cfg.seq_prefill:
        out = ssm.mlstm_prefill(pm, h, bs.mixer, ctx)
        if out is not None:            # chunkwise pass + final state (§Perf D)
            return out
    if bs.kind == "mlstm":
        shapes = ssm.mlstm_state_shapes(cfg, b, h.dtype)
        dec = functools.partial(ssm.mlstm_decode, specs=bs.mixer, ctx=ctx)
    elif bs.kind == "slstm":
        shapes = ssm.slstm_state_shapes(cfg, b)
        dec = functools.partial(ssm.slstm_decode, specs=bs.mixer, ctx=ctx)
    else:
        shapes = rglru.rglru_state_shapes(cfg, b, h.dtype)
        dec = functools.partial(rglru.rglru_decode, specs=bs.mixer, ctx=ctx)
    state0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    if bs.kind in ("mlstm",):
        state0["m"] = jnp.full_like(state0["m"], -1e30)
    if bs.kind == "slstm":
        state0["m"] = jnp.full_like(state0["m"], -1e30)

    def step(state, xt):
        y, state = dec(pm, xt[:, None], state)
        return state, y[:, 0]

    state, ys = jax.lax.scan(step, state0, jnp.moveaxis(h, 1, 0))
    return jnp.moveaxis(ys, 0, 1), state


def block_decode(p, x, cache, pos, bs: BlockSpecs, cfg: ArchConfig, ctx: ModelCtx,
                 *, pages=None):
    """One-token decode through a block. x: (B,1,D); pos: scalar or (B,).

    Returns (x, cache, st) — st per `_moe_ffn`."""
    h = common.norm_apply(p["norm1"], x, cfg.norm)
    if bs.kind in ("attn", "local"):
        sub = {k: v for k, v in cache.items() if k in ("k", "v")}
        m, sub = attention.attn_decode(p["mixer"], h, sub, pos, bs.mixer, cfg, ctx,
                                       window=_mixer_window(cfg, bs.kind),
                                       pages=pages)
        cache = {**cache, **sub}
    elif bs.kind == "mlstm":
        m, cache2 = ssm.mlstm_decode(p["mixer"], h, cache, bs.mixer, ctx)
        cache = {**cache, **cache2}
    elif bs.kind == "slstm":
        m, cache2 = ssm.slstm_decode(p["mixer"], h, cache, bs.mixer, ctx)
        cache = {**cache, **cache2}
    else:
        m, cache2 = rglru.rglru_decode(p["mixer"], h, cache, bs.mixer, ctx)
        cache = {**cache, **cache2}
    x = x + m
    if bs.cross:
        hc = common.norm_apply(p["norm_cross"], x, cfg.norm)
        x = x + attention.cross_attn_apply(
            p["mixer"], hc, (cache["cross_k"], cache["cross_v"]), bs.mixer, cfg, ctx)
    x, st = _moe_ffn(p, x, bs, cfg, ctx)
    return x, cache, st


def block_chunk(p, x, cache, pos0, bs: BlockSpecs, cfg: ArchConfig,
                ctx: ModelCtx, *, read_pages, write_pages, nreal):
    """Chunked-prefill through one block. x: (B, C, D); pos0: (B,).
    Returns (x, cache, st) — st per `_moe_ffn`.

    Only full-attention blocks are chunkable: window rings and recurrent
    states have no pageable representation of a partial prefix (the server
    falls back to whole-prompt prefill for those archs — `exact_prefill`).
    """
    if bs.kind != "attn":
        raise ValueError(f"chunked prefill requires attn blocks, got {bs.kind}")
    h = common.norm_apply(p["norm1"], x, cfg.norm)
    sub = {k: v for k, v in cache.items() if k in ("k", "v")}
    m, sub = attention.attn_prefill_chunk(
        p["mixer"], h, sub, pos0, bs.mixer, cfg, ctx,
        read_pages=read_pages, write_pages=write_pages, nreal=nreal)
    cache = {**cache, **sub}
    x = x + m
    x, st = _moe_ffn(p, x, bs, cfg, ctx)
    return x, cache, st


def block_pack(p, bs: BlockSpecs):
    """Train-layout block params -> packed serve layout."""
    out = {k: v for k, v in p.items() if k.startswith("norm")}
    m = p["mixer"]
    if bs.kind in ("attn", "local"):
        pm = {"qkv": qlinear.pack_params(m["qkv"], bs.mixer.qkv),
              "out": qlinear.pack_params(m["out"], bs.mixer.out)}
        if bs.cross:
            pm["cross_q"] = qlinear.pack_params(m["cross_q"], bs.mixer.cross_q)
            pm["cross_kv"] = qlinear.pack_params(m["cross_kv"], bs.mixer.cross_kv)
    elif bs.kind == "mlstm":
        pm = {"in_proj": qlinear.pack_params(m["in_proj"], bs.mixer.in_proj),
              "conv": m["conv"],
              "qkv": qlinear.pack_params(m["qkv"], bs.mixer.qkv),
              "gates": qlinear.pack_params(m["gates"], bs.mixer.gates),
              "out": qlinear.pack_params(m["out"], bs.mixer.out)}
    elif bs.kind == "slstm":
        pm = {"gates": qlinear.pack_params(m["gates"], bs.mixer.gates),
              "rec": m["rec"],
              "out": qlinear.pack_params(m["out"], bs.mixer.out)}
    else:
        pm = {"in_proj": qlinear.pack_params(m["in_proj"], bs.mixer.in_proj),
              "conv": m["conv"], "w_gates": m["w_gates"], "lam": m["lam"],
              "out": qlinear.pack_params(m["out"], bs.mixer.out)}
    out["mixer"] = pm
    if bs.ffn is not None:
        f = p["ffn"]
        if bs.is_moe:
            pf = {"router": qlinear.pack_params(f["router"], bs.ffn.router),
                  "up": qlinear.pack_params(f["up"], bs.ffn.up),
                  "down": qlinear.pack_params(f["down"], bs.ffn.down)}
            if "shared" in f:
                pf["shared"] = {
                    "up": qlinear.pack_params(f["shared"]["up"], bs.ffn.shared.up),
                    "down": qlinear.pack_params(f["shared"]["down"], bs.ffn.shared.down)}
        else:
            pf = {"up": qlinear.pack_params(f["up"], bs.ffn.up),
                  "down": qlinear.pack_params(f["down"], bs.ffn.down)}
        out["ffn"] = pf
    return out


# ---------------------------------------------------------------------------
# whole-model specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelSpecs:
    cfg: ArchConfig
    first: BlockSpecs
    mid: tuple[BlockSpecs, ...]       # one per pattern position (offset by 1)
    rem: tuple[BlockSpecs, ...]
    last: BlockSpecs
    n_periods: int
    embed_dim: int
    lm_head: Any
    encoder: tuple[BlockSpecs, ...] = ()


def build_specs(cfg: ArchConfig) -> ModelSpecs:
    pol = get_policy(cfg.policy)
    n, P = cfg.n_layers, len(cfg.block_pattern)
    cross = cfg.is_encdec
    if n < 2:
        raise ValueError("need >= 2 layers")
    n_mid = n - 2
    n_periods = n_mid // P if cfg.scan_layers else 0
    n_rem = n_mid - n_periods * P
    first = block_specs(cfg, pol, cfg.pattern_at(0), first=True, cross=cross)
    mid = tuple(block_specs(cfg, pol, cfg.pattern_at(1 + t), cross=cross)
                for t in range(P)) if n_periods else ()
    rem = tuple(block_specs(cfg, pol, cfg.pattern_at(1 + n_periods * P + t), cross=cross)
                for t in range(n_rem))
    last = block_specs(cfg, pol, cfg.pattern_at(n - 1), last=True, cross=cross)
    # lm_head is column-parallel under serve TP: vocab-sharded logits, no
    # collective (argmax over the sharded vocab axis is exact)
    lm_head = common.lspec(pol, "lm_head", cfg.d_model, cfg.vocab, last=True,
                           parallel="column")
    encoder = tuple(block_specs(cfg, pol, "attn") for _ in range(cfg.encoder_layers))
    return ModelSpecs(cfg, first, mid, rem, last, n_periods, cfg.d_model,
                      lm_head, encoder)


def init(rng, cfg: ArchConfig) -> dict:
    """Train-layout parameters."""
    sp = build_specs(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(rng, 8)
    p: dict[str, Any] = {
        "embed": common.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "first": block_init(keys[1], cfg, sp.first, dtype),
        "last": block_init(keys[2], cfg, sp.last, dtype),
        "final_norm": common.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = common.linear_init(keys[3], sp.lm_head, dtype)
    if sp.n_periods:
        def period_init(k):
            kk = jax.random.split(k, len(sp.mid))
            return {f"b{t}": block_init(kk[t], cfg, sp.mid[t], dtype)
                    for t in range(len(sp.mid))}
        p["mid"] = jax.vmap(period_init)(jax.random.split(keys[4], sp.n_periods))
    for t, bs in enumerate(sp.rem):
        p[f"rem{t}"] = block_init(jax.random.fold_in(keys[5], t), cfg, bs, dtype)
    for t, bs in enumerate(sp.encoder):
        p[f"enc{t}"] = block_init(jax.random.fold_in(keys[6], t), cfg, bs, dtype)
    if sp.encoder:
        p["enc_norm"] = common.norm_init(cfg.d_model, cfg.norm, dtype)
    return p


def _strip_plane_twins(t):
    if isinstance(t, dict):
        return {k: _strip_plane_twins(v)
                for k, v in t.items() if k != "w_planes"}
    return t


def pack_for_serve(params: dict, cfg: ArchConfig, *,
                   plane_twins: bool = False) -> dict:
    """Convert train-layout params to the packed serve layout (bit-planes).

    `plane_twins=True` keeps the stacked bit-plane twin (`w_planes`) that
    `qlinear.pack_params` emits next to the direct int4/int8 layout — the
    `impl="planes"` cells and the `--spec-draft` truncated-plane draft read
    it. The default strips it: the twin duplicates those layers' weight
    bytes, and the paper's packed-footprint ladder (binary < ternary < int8
    < none) is a claim about the serving layout, not the plane machinery.
    """
    sp = build_specs(cfg)
    out: dict[str, Any] = {
        "embed": {"w": params["embed"]["w"].astype(jnp.bfloat16)},
        "first": block_pack(params["first"], sp.first),
        "last": block_pack(params["last"], sp.last),
        "final_norm": params["final_norm"],
    }
    if "lm_head" in params:
        out["lm_head"] = qlinear.pack_params(params["lm_head"], sp.lm_head)
    if sp.n_periods:
        def pp(period):
            return {f"b{t}": block_pack(period[f"b{t}"], sp.mid[t])
                    for t in range(len(sp.mid))}
        out["mid"] = jax.vmap(pp)(params["mid"])
    for t, bs in enumerate(sp.rem):
        out[f"rem{t}"] = block_pack(params[f"rem{t}"], bs)
    for t, bs in enumerate(sp.encoder):
        out[f"enc{t}"] = block_pack(params[f"enc{t}"], bs)
    if sp.encoder:
        out["enc_norm"] = params["enc_norm"]
    return out if plane_twins else _strip_plane_twins(out)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _encode(params, sp: ModelSpecs, audio_embeds, ctx: ModelCtx):
    cfg = sp.cfg
    x = audio_embeds.astype(ctx.dtype)
    x = x + common.sinusoidal_positions(x.shape[1], cfg.d_model).astype(ctx.dtype)
    for t, bs in enumerate(sp.encoder):
        x, _ = block_apply(params[f"enc{t}"], x, bs, cfg, ctx, causal=False)
    return common.norm_apply(params["enc_norm"], x, cfg.norm)


def _stack_apply(params, x, sp: ModelSpecs, ctx: ModelCtx, *, enc_out=None):
    """first -> scanned periods -> remainder -> last. Returns (x, aux)."""
    cfg = sp.cfg
    sa = lambda t: common.shard_act(t, ctx)
    x, aux = block_apply(params["first"], sa(x), sp.first, cfg, ctx, enc_out=enc_out)

    if sp.n_periods:
        def period(xc, pp):
            xx, a = xc
            for t, bs in enumerate(sp.mid):
                xx2, a2 = block_apply(pp[f"b{t}"], sa(xx), bs, cfg, ctx, enc_out=enc_out)
                xx, a = sa(xx2), a + a2
            return (xx, a), None
        # remat policy: recompute activations but SAVE the gathered quantized
        # weights (tiny per period; re-gathering them in bwd recompute was
        # ~3x the FSDP gather volume — §Perf B iter-6)
        body = jax.checkpoint(
            period,
            policy=jax.checkpoint_policies.save_only_these_names("qweight"),
        ) if cfg.remat else period
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["mid"])

    for t, bs in enumerate(sp.rem):
        x, a = block_apply(params[f"rem{t}"], sa(x), bs, cfg, ctx, enc_out=enc_out)
        aux = aux + a
    x, a = block_apply(params["last"], sa(x), sp.last, cfg, ctx, enc_out=enc_out)
    return common.shard_act(x, ctx), aux + a


def _logits(params, x, sp: ModelSpecs, ctx: ModelCtx):
    x = common.norm_apply(params["final_norm"], x, sp.cfg.norm)
    if sp.cfg.tie_embeddings:
        return (x @ params["embed"]["w"].astype(x.dtype).T).astype(jnp.float32)
    return common.linear_apply(params["lm_head"], x, sp.lm_head, ctx).astype(jnp.float32)


def forward(params, tokens, sp: ModelSpecs, ctx: ModelCtx, *,
            frontend_embeds=None):
    """Teacher-forcing forward. tokens: (B, T) -> logits (B, T(+Np), V), aux.

    VLM: frontend_embeds (B, Np, D) are prepended (loss masking is the
    caller's job via the returned prefix length).
    Audio (enc-dec): frontend_embeds (B, Tenc, D) go through the encoder.
    """
    cfg = sp.cfg
    x = common.shard_act(common.embed_apply(params["embed"], tokens, ctx.dtype), ctx)
    enc_out = None
    prefix = 0
    if cfg.is_encdec and frontend_embeds is not None:
        enc_out = _encode(params, sp, frontend_embeds, ctx)
    elif cfg.frontend == "vision" and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(ctx.dtype), x], axis=1)
        prefix = frontend_embeds.shape[1]
    x, aux = _stack_apply(params, x, sp, ctx, enc_out=enc_out)
    return _logits(params, x, sp, ctx), aux, prefix


def loss_fn(params, batch, sp: ModelSpecs, ctx: ModelCtx):
    """Cross-entropy next-token loss. batch: {tokens, targets[, frontend]}"""
    logits, aux, prefix = forward(params, batch["tokens"], sp, ctx,
                                  frontend_embeds=batch.get("frontend"))
    if prefix:
        logits = logits[:, prefix:]
    loss = common.cross_entropy(logits, batch["targets"])
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def cache_shapes(cfg: ArchConfig, batch: int, seq_len: int,
                 paged: tuple[int, int] | None = None, kv_dtype=None):
    """Decode-cache ShapeDtypeStructs. `paged=(num_pages, page_size)` puts
    full-attention KV into the shared block pool (see launch/kv_cache.py);
    window rings and recurrent states stay per-slot slabs.

    `kv_dtype` overrides the attention KV storage dtype (None =>
    cfg.kv_cache_dtype). The serve loop passes its compute dtype so the pool
    matches what `attn_apply`/`attn_decode` actually store — prefill caches
    follow the compute dtype unless the int8-requant cache is on.
    """
    sp = build_specs(cfg)
    shapes: dict[str, Any] = {
        "first": block_cache_shapes(cfg, sp.first, batch, seq_len, paged, kv_dtype),
        "last": block_cache_shapes(cfg, sp.last, batch, seq_len, paged, kv_dtype),
    }
    if sp.n_periods:
        def stack(tree):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((sp.n_periods,) + s.shape, s.dtype), tree)
        shapes["mid"] = stack({f"b{t}": block_cache_shapes(cfg, bs, batch, seq_len,
                                                           paged, kv_dtype)
                               for t, bs in enumerate(sp.mid)})
    for t, bs in enumerate(sp.rem):
        shapes[f"rem{t}"] = block_cache_shapes(cfg, bs, batch, seq_len, paged,
                                               kv_dtype)
    return shapes


def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               paged: tuple[int, int] | None = None, kv_dtype=None):
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         cache_shapes(cfg, batch, seq_len, paged, kv_dtype))
    return _fix_m_states(cache, cfg)


def _fix_m_states(cache, cfg):
    """m-stabilizer states start at -inf (see ssm.py)."""
    def fix(path, leaf):
        names = [getattr(k, "key", "") for k in path]
        if names and names[-1] == "m":
            return jnp.full_like(leaf, -1e30)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, cache)


def prefill(params, tokens, sp: ModelSpecs, ctx: ModelCtx, *, frontend_embeds=None,
            cache_len: int = 0, last_pos=None):
    """Process the prompt, return (last-position logits, cache).

    `cache_len`: KV-cache capacity to allocate (0 => prompt length; pass
    prompt_len + max_new_tokens for generation).
    `last_pos`: (B,) index of each row's final *real* token when `tokens` is
    right-padded to a bucket length (continuous-batching prefill); None =>
    the literal last column. Causal masking keeps real positions from
    attending to the padding, so the cache below `last_pos` is unaffected.

    Under ctx.moe_stats (MoE archs), returns (logits, cache, moe_stats) —
    the per-block routing counters summed over the stack. NOTE: prefill
    routes padding rows too, so expert_tokens/dropped include bucket-padding
    traffic (same for the sequential oracle — counters stay comparable).
    """
    cfg = sp.cfg
    x = common.shard_act(common.embed_apply(params["embed"], tokens, ctx.dtype), ctx)
    enc_out = None
    if cfg.is_encdec and frontend_embeds is not None:
        enc_out = _encode(params, sp, frontend_embeds, ctx)
    elif cfg.frontend == "vision" and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(ctx.dtype), x], axis=1)
    cache_len = cache_len or x.shape[1]

    moe_tot = _moe_zero(cfg) if (ctx.moe_stats and cfg.n_experts) else None
    caches: dict[str, Any] = {}
    x, caches["first"], st = block_prefill(params["first"], x, sp.first, cfg, ctx,
                                           enc_out=enc_out, cache_len=cache_len)
    moe_tot = _moe_add(moe_tot, st)
    if sp.n_periods:
        def period(carry, pp):
            xx, tot = carry
            cs = {}
            for t, bs in enumerate(sp.mid):
                xx, cs[f"b{t}"], st = block_prefill(pp[f"b{t}"], xx, bs, cfg, ctx,
                                                    enc_out=enc_out,
                                                    cache_len=cache_len)
                tot = _moe_add(tot, st)
            return (xx, tot), cs
        (x, moe_tot), caches["mid"] = jax.lax.scan(period, (x, moe_tot),
                                                   params["mid"])
    for t, bs in enumerate(sp.rem):
        x, caches[f"rem{t}"], st = block_prefill(params[f"rem{t}"], x, bs, cfg, ctx,
                                                 enc_out=enc_out, cache_len=cache_len)
        moe_tot = _moe_add(moe_tot, st)
    x, caches["last"], st = block_prefill(params["last"], x, sp.last, cfg, ctx,
                                          enc_out=enc_out, cache_len=cache_len)
    moe_tot = _moe_add(moe_tot, st)
    if last_pos is None:
        x_last = x[:, -1:]
    else:
        idx = jnp.asarray(last_pos, jnp.int32).reshape(-1, 1, 1)
        x_last = jnp.take_along_axis(x, idx, axis=1)
    logits = _logits(params, x_last, sp, ctx)
    if ctx.moe_stats:
        return logits, caches, moe_tot
    return logits, caches


def _chunk_stack(params, cache, tokens, pos0, sp: ModelSpecs, ctx: ModelCtx,
                 kw):
    """Shared multi-token paged traversal: embed `tokens` (B, C) and run the
    chunk path (attention reads prior pool KV + the chunk's own causal
    prefix, writes the chunk KV through `write_pages`) through every block.
    Returns (hidden (B, C, D), new_cache, moe_tot) — moe_tot per the
    ctx.moe_stats contract (None when off). Backs both `prefill_chunk`
    (chunked prompt prefill) and `decode_verify` (speculative multi-token
    verification) — one algebra, two logits policies."""
    cfg = sp.cfg
    x = common.shard_act(common.embed_apply(params["embed"], tokens, ctx.dtype), ctx)
    moe_tot = _moe_zero(cfg) if (ctx.moe_stats and cfg.n_experts) else None
    new_cache: dict[str, Any] = {}
    x, new_cache["first"], st = block_chunk(params["first"], x, cache["first"], pos0,
                                            sp.first, cfg, ctx, **kw)
    moe_tot = _moe_add(moe_tot, st)
    if sp.n_periods:
        def period(carry, scanned):
            xx, tot = carry
            pp, cc = scanned
            ncs = {}
            for t, bs in enumerate(sp.mid):
                xx, ncs[f"b{t}"], st = block_chunk(pp[f"b{t}"], xx, cc[f"b{t}"],
                                                   pos0, bs, cfg, ctx, **kw)
                tot = _moe_add(tot, st)
            return (xx, tot), ncs
        (x, moe_tot), new_cache["mid"] = jax.lax.scan(
            period, (x, moe_tot), (params["mid"], cache["mid"]))
    for t, bs in enumerate(sp.rem):
        x, new_cache[f"rem{t}"], st = block_chunk(params[f"rem{t}"], x,
                                                  cache[f"rem{t}"], pos0, bs,
                                                  cfg, ctx, **kw)
        moe_tot = _moe_add(moe_tot, st)
    x, new_cache["last"], st = block_chunk(params["last"], x, cache["last"], pos0,
                                           sp.last, cfg, ctx, **kw)
    moe_tot = _moe_add(moe_tot, st)
    return x, new_cache, moe_tot


def prefill_chunk(params, cache, tokens, pos0, sp: ModelSpecs, ctx: ModelCtx, *,
                  read_pages, write_pages, nreal, last_idx):
    """One prompt *chunk* through the stack against the paged cache.

    tokens: (B, C) — C chunk tokens starting at absolute position pos0 (B,),
    right-padded past `nreal` (B,). read_pages/write_pages: (B, max_pages)
    page rows (write row has NULL_PAGE at shared-prefix pages). Returns
    (logits, cache) where logits (B, 1, V) are taken at chunk-local index
    `last_idx` (B,) — only meaningful on the final chunk of a prompt, where
    the server points it at the prompt's last token to sample the first
    output (garbage otherwise, ignored by the caller).

    Byte-exactness: each chunk writes exactly the KV bytes whole-prompt
    `prefill` would (see attention.attn_prefill_chunk), and the final chunk's
    last-row hidden state is bit-identical to whole-prompt `last_pos` gather,
    so the sampled first token matches the sequential oracle.
    """
    kw = dict(read_pages=read_pages, write_pages=write_pages, nreal=nreal)
    x, new_cache, moe_tot = _chunk_stack(params, cache, tokens, pos0, sp, ctx, kw)
    idx = jnp.asarray(last_idx, jnp.int32).reshape(-1, 1, 1)
    x_last = jnp.take_along_axis(x, idx, axis=1)
    logits = _logits(params, x_last, sp, ctx)
    if ctx.moe_stats:
        return logits, new_cache, moe_tot
    return logits, new_cache


def decode_verify(params, cache, tokens, pos0, sp: ModelSpecs, ctx: ModelCtx, *,
                  read_pages, write_pages, nreal):
    """Full-precision multi-token VERIFY step for self-speculative decoding.

    tokens: (B, K) — row b is [last accepted token, draft_0, .., draft_{K-2}]
    at absolute positions pos0[b] .. pos0[b]+K-1, right-padded past nreal[b]
    (slots verifying fewer than K tokens this tick). Same chunk algebra as
    `prefill_chunk` — causal attention over prior pool KV plus the chunk's
    own prefix, KV scattered through `write_pages` — but logits are returned
    for EVERY chunk row (B, K, V): row i is the exact next-token distribution
    after consuming tokens[:, :i+1], i.e. what sequential `decode_step` would
    produce at position pos0+i. The server samples each row with the same
    stateless (seed, index) rng as sequential decode and accepts the longest
    draft prefix that matches — so speculative serving stays token-exact.

    KV written for rows past the accepted prefix is garbage from rejected
    draft inputs; it is harmless because every future decode write lands at
    the slot's (rewound) position before any read reaches it, and the
    scheduler forks shared pages across the whole [pos0, pos0+K) write range
    before dispatch (see launch/serve.py `_spec_tick`).
    """
    kw = dict(read_pages=read_pages, write_pages=write_pages, nreal=nreal)
    x, new_cache, moe_tot = _chunk_stack(params, cache, tokens, pos0, sp, ctx, kw)
    logits = _logits(params, x, sp, ctx)
    if ctx.moe_stats:
        return logits, new_cache, moe_tot
    return logits, new_cache


def decode_step(params, cache, tokens, pos, sp: ModelSpecs, ctx: ModelCtx, *,
                pages=None):
    """One decode step. tokens: (B, 1); pos: scalar int32 (aligned decode) or
    (B,) int32 — one position per slot (continuous batching).

    `pages`: (B, max_pages) int32 page table when the cache was built with
    `init_cache(..., paged=(num_pages, page_size))`; full-attention layers
    then write/read through the page lists (see launch/kv_cache.py).

    This is the `serve_step` the decode_* dry-run shapes lower. Under
    ctx.moe_stats, returns (logits, cache, moe_stats) — counters include the
    padding/parked slots in the batch (they decode like real slots; the
    oracle pads identically, so comparisons stay exact).
    """
    cfg = sp.cfg
    x = common.shard_act(common.embed_apply(params["embed"], tokens, ctx.dtype), ctx)
    moe_tot = _moe_zero(cfg) if (ctx.moe_stats and cfg.n_experts) else None
    new_cache: dict[str, Any] = {}
    x, new_cache["first"], st = block_decode(params["first"], x, cache["first"], pos,
                                             sp.first, cfg, ctx, pages=pages)
    moe_tot = _moe_add(moe_tot, st)
    if sp.n_periods:
        def period(carry, scanned):
            xx, tot = carry
            pp, cc = scanned
            ncs = {}
            for t, bs in enumerate(sp.mid):
                xx, ncs[f"b{t}"], st = block_decode(pp[f"b{t}"], xx, cc[f"b{t}"],
                                                    pos, bs, cfg, ctx, pages=pages)
                tot = _moe_add(tot, st)
            return (xx, tot), ncs
        (x, moe_tot), new_cache["mid"] = jax.lax.scan(
            period, (x, moe_tot), (params["mid"], cache["mid"]))
    for t, bs in enumerate(sp.rem):
        x, new_cache[f"rem{t}"], st = block_decode(params[f"rem{t}"], x,
                                                   cache[f"rem{t}"], pos, bs,
                                                   cfg, ctx, pages=pages)
        moe_tot = _moe_add(moe_tot, st)
    x, new_cache["last"], st = block_decode(params["last"], x, cache["last"], pos,
                                            sp.last, cfg, ctx, pages=pages)
    moe_tot = _moe_add(moe_tot, st)
    logits = _logits(params, x, sp, ctx)
    if ctx.moe_stats:
        return logits, new_cache, moe_tot
    return logits, new_cache
