"""GQA attention: full/causal/sliding-window, blockwise (flash-style) prefill,
KV-cache decode (linear + ring-buffer), optional cross-attention.

The QKV/O projections are `QuantizedLinear`s — in BrainTTA terms these are the
vMAC GEMMs; the softmax/AV math stays wide (bf16/f32), mirroring the SoC's
wide accumulator path. Blockwise attention keeps the (Tq × Tk) score matrix
tiled (q_block × kv_block), which is mandatory at 32k+ context.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.precision import PrecisionPolicy

from . import common
from .common import ModelCtx

NEG_INF = -1e30
KV_SCALE = 0.05   # static requant scale for the int8 KV cache (§Perf C)


def _kv_quant(t, dtype):
    """Requantize K/V for cache storage (paper §IV-A requantization applied
    to the cache): int8 codes at a static scale, or passthrough cast."""
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(t.astype(jnp.float32) / KV_SCALE),
                        -127, 127).astype(jnp.int8)
    return t.astype(dtype)


def _kv_dequant(c, compute_dtype):
    if c.dtype == jnp.int8:
        return (c.astype(jnp.float32) * KV_SCALE).astype(compute_dtype)
    return c.astype(compute_dtype)


@dataclasses.dataclass(frozen=True)
class AttnSpecs:
    qkv: Any
    out: Any
    cross_q: Any = None
    cross_kv: Any = None


def attn_specs(cfg: ArchConfig, pol: PrecisionPolicy, *, first=False, last=False,
               cross: bool = False) -> AttnSpecs:
    # Megatron pairing for serve TP: qkv is column-parallel (head/out dim
    # sharded, no collective), the out projection is row-parallel (packed-K
    # sharded, one pre-requant psum) — so each attention block costs exactly
    # one TP collective. Only active when a serve mesh threads ctx.tp.
    h, hk, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    mk = lambda lc, i, o, bias=False, par="none": common.lspec(
        pol, lc, i, o, first=first, last=last, bias=bias, parallel=par)
    return AttnSpecs(
        qkv=mk("attn_qkv", d, (h + 2 * hk) * dh, bias=cfg.qkv_bias,
               par="column"),
        out=mk("attn_out", h * dh, d, par="row"),
        cross_q=mk("attn_qkv", d, h * dh, par="column") if cross else None,
        cross_kv=mk("attn_qkv", d, 2 * hk * dh, par="column") if cross else None,
    )


def attn_init(rng, cfg: ArchConfig, specs: AttnSpecs, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p = {"qkv": common.linear_init(ks[0], specs.qkv, dtype),
         "out": common.linear_init(ks[1], specs.out, dtype)}
    if specs.cross_q is not None:
        p["cross_q"] = common.linear_init(ks[2], specs.cross_q, dtype)
        p["cross_kv"] = common.linear_init(ks[3], specs.cross_kv, dtype)
    return p


def _split_qkv(y: jnp.ndarray, cfg: ArchConfig):
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, t, _ = y.shape
    q, k, v = jnp.split(y, [h * dh, (h + hk) * dh], axis=-1)
    return (q.reshape(b, t, h, dh), k.reshape(b, t, hk, dh), v.reshape(b, t, hk, dh))


def _gqa_scores_blockless(q, k, v, mask):
    """Reference small-scale attention. q: (B,Tq,H,dh) k/v: (B,Tk,Hk,dh)."""
    b, tq, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, tq, hk, g, dh)
    s = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32) / dh ** 0.5
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgts,bshd->bthgd", a, v)
    return o.reshape(b, tq, h, dh)


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_block: int = 512, kv_block: int = 1024,
                        q_offset=0, cp: bool = False) -> jnp.ndarray:
    """Flash-style blocked attention with online softmax.

    q: (B, Tq, H, dh); k,v: (B, Tk, Hk, dh). `window`>0 restricts each query
    to the last `window` keys (sliding-window / local attention), which also
    shrinks the kv loop to the band — sub-quadratic in T.
    `q_offset`: absolute position of q[0] (prefill continuation / decode).

    Two schedules:
      cp=False  two-level scan (q blocks x kv blocks) — bounds the score temp
                to (B, qb, H, kvb); used on host-scale runs and window layers.
      cp=True   context-parallel: the caller sharded Tq over the model axis,
                so the score temp is already bounded by the T shard; a single
                kv scan keeps every tensor's Tq dim intact (reshapes that
                split a sharded dim break GSPMD propagation — measured 2.4x
                compute + 200 GiB gather churn; see EXPERIMENTS.md §Perf).
    """
    b, tq, h, dh = q.shape
    tk, hk = k.shape[1], k.shape[2]
    g = h // hk
    q_block = min(q_block, tq)
    kv_block = min(kv_block, tk)
    if tq % q_block or tk % kv_block:           # fallback for odd smoke shapes
        mask = jnp.ones((b, tq, tk), bool)
        pos_q = jnp.arange(tq) + q_offset
        pos_k = jnp.arange(tk)
        if causal:
            mask &= pos_q[None, :, None] >= pos_k[None, None, :]
        if window:
            mask &= pos_q[None, :, None] - pos_k[None, None, :] < window
        return _gqa_scores_blockless(q, k, v, mask)

    nq, nk = tq // q_block, tk // kv_block
    scale = 1.0 / dh ** 0.5

    # how many kv blocks each q block needs to visit
    if window:
        band = window + q_block                     # kv span per q block
        nkv_visit = min(nk, (band + kv_block - 1) // kv_block + 1)
    else:
        nkv_visit = nk

    def kv_scan(qi_blk, q_pos, start_blk, n_visit):
        """Online-softmax sweep of kv blocks for one q block.
        qi_blk: (B, Tq', Hk, G, dh)."""
        tq_ = qi_blk.shape[1]

        def kv_step(carry, j):
            m, l, acc = carry
            kj = start_blk + j
            ks = jax.lax.dynamic_slice_in_dim(k, kj * kv_block, kv_block, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, kj * kv_block, kv_block, axis=1)
            s = jnp.einsum("bqhgd,bshd->bhgqs", qi_blk, ks).astype(jnp.float32) * scale
            k_pos = kj * kv_block + jnp.arange(kv_block)
            msk = jnp.ones((tq_, kv_block), bool)
            if causal:
                msk &= q_pos[:, None] >= k_pos[None, :]
            if window:
                msk &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqs,bshd->bhgqd", p.astype(q.dtype), vs).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hk, g, tq_), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, tq_), jnp.float32)
        a0 = jnp.zeros((b, hk, g, tq_, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_visit))
        o = acc / jnp.maximum(l, 1e-20)[..., None]
        return o.astype(q.dtype)                     # (B, Hk, G, Tq', dh)

    if cp:
        # single-level: whole (sequence-sharded) q against the kv sweep
        qg = q.reshape(b, tq, hk, g, dh)
        o = kv_scan(qg, q_offset + jnp.arange(tq), 0, nk)
        o = jnp.moveaxis(o, 3, 1)                    # (B, Tq, Hk, G, dh)
        return o.reshape(b, tq, h, dh)

    qb = q.reshape(b, nq, q_block, hk, g, dh)

    def q_step(_, qi):
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)
        if window:
            start_blk = jnp.clip((q_offset + qi * q_block - window) // kv_block,
                                 0, nk - nkv_visit)
        else:
            start_blk = 0
        return None, kv_scan(qb[:, qi], q_pos, start_blk, nkv_visit)

    _, ob = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq, B, Hk, G, qblk, dh)
    o = jnp.moveaxis(ob, 0, 1)                            # (B, nq, Hk, G, qblk, dh)
    o = jnp.moveaxis(o, -2, 2)                            # (B, nq, qblk, Hk, G, dh)
    return o.reshape(b, tq, h, dh)


# ---------------------------------------------------------------------------
# block-level apply: prefill/train, decode, cross-attention
# ---------------------------------------------------------------------------

def attn_apply(p, x, specs: AttnSpecs, cfg: ArchConfig, ctx: ModelCtx, *,
               causal: bool = True, window: int = 0, positions=None,
               return_cache: bool = False, cache_len: int = 0):
    """Full-sequence attention (train / prefill). x: (B, T, D).

    With return_cache: the KV cache is laid out for `attn_decode` —
    full-attention layers get `cache_len` (>= T) linear slots; window layers
    get a ring buffer of capacity min(window, cache_len) where position p
    lives at slot p % capacity.
    """
    b, t, _ = x.shape
    y = common.linear_apply(p["qkv"], x, specs.qkv, ctx)
    q, k, v = _split_qkv(y, cfg)
    if positions is None:
        positions = jnp.arange(t)
    q = common.rope(q, positions, cfg.rope_theta)
    k = common.rope(k, positions, cfg.rope_theta)
    # serve TP: heads arrive model-sharded from the column-parallel qkv
    # shard_map (its out_specs put the fused head dim on the model axis) and
    # GSPMD propagates that through split/rope into the per-head score/AV
    # einsums. Do NOT re-pin the head axis with an explicit
    # with_sharding_constraint here: on the CPU SPMD backend that constraint
    # miscompiles the blocked-attention scan (wrong values, not just layout
    # churn) — the serving TP oracle in tests/test_serving_tp.py catches it.
    if (ctx.backend == "pallas" and not window and t % 256 == 0
            and ctx.attn_cp is None):
        # TPU deployment path: fused flash-attention kernel (kernels/flash_attn)
        from repro.kernels.flash_attn import flash_attention as _flash
        import os as _os
        interp = _os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"
        b_, t_, h_, dh_ = q.shape
        hk_ = k.shape[2]
        qf = jnp.moveaxis(q, 2, 1).reshape(b_ * h_, t_, dh_)
        kf = jnp.moveaxis(k, 2, 1).reshape(b_ * hk_, t_, dh_)
        vf = jnp.moveaxis(v, 2, 1).reshape(b_ * hk_, t_, dh_)
        of = _flash(qf, kf, vf, causal=causal, interpret=interp)
        o = jnp.moveaxis(of.reshape(b_, h_, t_, dh_), 1, 2)
        out = common.linear_apply(p["out"], o.reshape(b, t, -1), specs.out, ctx)
        if return_cache:
            cap = max(cache_len or t, 1)
            if t < cap:
                pad = ((0, 0), (0, cap - t), (0, 0), (0, 0))
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            cd = jnp.int8 if cfg.kv_cache_dtype == "int8" else k.dtype
            return out, {"k": _kv_quant(k, cd), "v": _kv_quant(v, cd)}
        return out

    cp = bool(ctx.attn_cp) and not window and t % 512 == 0
    if cp:
        # context parallelism: q sequence sharded over the model axis, kv
        # replicated within the dp group — head-count agnostic (llama 24H/8KV
        # doesn't divide a 16-way model axis; head-TP would pad & churn).
        q = common.shard_spec(q, ctx, ctx.attn_cp, None, None)
        k = common.shard_spec(k, ctx, None, None, None)
        v = common.shard_spec(v, ctx, None, None, None)
    elif ctx.attn_cp and window:
        # window layers: cheap (banded) — replicate over model inside the dp
        # group rather than churn on reshapes; see DESIGN.md §Perf notes
        q = common.shard_spec(q, ctx, None, None, None)
        k = common.shard_spec(k, ctx, None, None, None)
        v = common.shard_spec(v, ctx, None, None, None)
    o = blockwise_attention(q, k, v, causal=causal, window=window, cp=cp)
    if cp:
        o = common.shard_spec(o, ctx, ctx.attn_cp, None, None)
    out = common.linear_apply(p["out"], o.reshape(b, t, -1), specs.out, ctx)
    if return_cache:
        cap = max(cache_len or t, 1)
        if window:
            cap = min(window, cap)
        if t > cap:
            k, v = k[:, -cap:], v[:, -cap:]
            if window:                      # ring alignment: slot = pos % cap
                k = jnp.roll(k, t % cap, axis=1)
                v = jnp.roll(v, t % cap, axis=1)
        elif t < cap:
            pad = ((0, 0), (0, cap - t), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        # int8 cache when requested; otherwise the cache follows the compute
        # dtype (so f32 verification runs stay exact)
        cd = jnp.int8 if cfg.kv_cache_dtype == "int8" else k.dtype
        return out, {"k": _kv_quant(k, cd), "v": _kv_quant(v, cd)}
    return out


def init_cache_shapes(cfg: ArchConfig, batch: int, seq_len: int, window: int,
                      dtype=None, paged: tuple[int, int] | None = None):
    """Cache ShapeDtypeStructs for one attention layer.

    `paged=(num_pages, page_size)` switches full-attention layers to the
    block-pool layout (num_pages, page_size, Hk, dh) shared by every slot via
    a page table (launch/kv_cache.py). Window layers keep their per-slot ring
    buffers — the ring is already bounded at `window` tokens, so paging it
    buys nothing.
    """
    if dtype is None:
        dtype = jnp.dtype(cfg.kv_cache_dtype)
    if paged is not None and not window:
        num_pages, page_size = paged
        shp = (num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    else:
        s = min(window, seq_len) if window else seq_len
        shp = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype)}


def attn_decode(p, x, cache, pos, specs: AttnSpecs, cfg: ArchConfig,
                ctx: ModelCtx, *, window: int = 0, pages=None):
    """One-token decode. x: (B, 1, D); pos: scalar or per-row (B,) int32.

    Per-row positions drive RoPE phases, the cache-write index, and the
    validity mask independently per slot (continuous batching: slots decode
    at unrelated positions).

    Layouts:
      contiguous  cache k/v: (B, S|W, Hk, dh); full attention writes at
                  index pos[b], window layers ring-write at pos[b] % W.
      paged       cache k/v: (num_pages, page_size, Hk, dh) + `pages`
                  (B, max_pages) page table; writes go to
                  pages[b, pos[b]//P] at offset pos[b] % P, reads gather the
                  row's page list back into a (B, max_pages*P, Hk, dh) view.
                  Unallocated table entries point at page 0 (scratch); reads
                  from it are masked by `valid`, writes to it are discarded
                  garbage by construction.

    Prefix sharing contract: one physical page may appear in SEVERAL rows of
    `pages` (requests aliasing a common prompt prefix) — the gather-based
    read path is oblivious to that. The write below is only safe because the
    scheduler forks shared pages BEFORE handing the table to this step
    (copy-on-write in launch/serve.py `_prepare_pages` via
    kv_cache.fork_cow + copy_page): by contract, `pages[b, pos[b]//P]` is
    exclusively owned by row b whenever row b is active. Do not add writes
    through `pages` anywhere else without routing them past that fork.

    Paged read paths (ctx.paged_attn; docs/SERVING.md §Paged-attention
    decode kernel): the jnp gather path above is the oracle; with
    backend="pallas" (or paged_attn="fused") the read side instead runs
    `kernels.paged_attn.paged_flash_decode`, which walks the SAME post-fork
    table page by page inside the kernel (scalar-prefetched `pages`/`pos`,
    per-page DMA + online softmax) — the write side below is shared by both,
    so the CoW contract is path-independent. When `pos` is concrete (eager
    oracle/bench callers — under the server's jit it is a tracer and the
    table width is part of the fixed decode signature), the table is first
    sliced to max(pos)//P + 1 columns so neither path touches dead pages.
    """
    b = x.shape[0]
    y = common.linear_apply(p["qkv"], x, specs.qkv, ctx)
    q, k_new, v_new = _split_qkv(y, cfg)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))       # (B,)
    posv = posb[:, None]
    q = common.rope(q, posv, cfg.rope_theta)
    k_new = common.rope(k_new, posv, cfg.rope_theta)
    # serve TP: head-sharded decode falls out of the column-parallel qkv
    # shard_map out_specs (see attn_apply — no explicit head re-pin here)

    cd = cache["k"].dtype
    kq, vq = _kv_quant(k_new, cd)[:, 0], _kv_quant(v_new, cd)[:, 0]  # (B,Hk,dh)
    rows = jnp.arange(b)
    if pages is not None and not window:
        page_size = cache["k"].shape[1]
        if not isinstance(posb, jax.core.Tracer):
            # eager caller (oracle tests / benches): length-bound the table
            # to the last active page — dead pages past max(pos) are neither
            # gathered/dequantized nor walked by the kernel. Under jit `pos`
            # is a tracer and the full (fixed-signature) width stays.
            pages = pages[:, :int(jnp.max(posb)) // page_size + 1]
        pid = pages[rows, posb // page_size]
        off = posb % page_size
        k = cache["k"].at[pid, off].set(kq)
        v = cache["v"].at[pid, off].set(vq)
        fused = (ctx.paged_attn == "fused"
                 or (ctx.paged_attn == "auto" and ctx.backend == "pallas"))
        if fused:
            # fused page-walk kernel (reads the same post-fork table and the
            # post-write pool, so CoW/write semantics match the gather path)
            from repro.kernels import paged_attn as _pa
            from repro.kernels.dispatch import INTERPRET as _interp
            h_, hk_, dh_ = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            of = _pa.paged_flash_decode(
                q[:, 0], k, v, pages, posb,
                pages_per_block=_pa.resolve_pages_per_block(ctx.tune),
                kv_scale=KV_SCALE, interpret=_interp)
            out = common.linear_apply(p["out"], of.reshape(b, 1, h_ * dh_),
                                      specs.out, ctx)
            return out, {"k": k, "v": v}
        s = pages.shape[1] * page_size
        kf = _kv_dequant(k[pages].reshape(b, s, *k.shape[2:]), x.dtype)
        vf = _kv_dequant(v[pages].reshape(b, s, *v.shape[2:]), x.dtype)
        valid = jnp.arange(s)[None, :] <= posb[:, None]               # (B, S)
    else:
        s = cache["k"].shape[1]
        idx = (posb % s) if window else jnp.minimum(posb, s - 1)      # (B,)
        k = cache["k"].at[rows, idx].set(kq)
        v = cache["v"].at[rows, idx].set(vq)
        kf, vf = _kv_dequant(k, x.dtype), _kv_dequant(v, x.dtype)
        slots = jnp.arange(s)
        if window:
            # ring full => every slot valid
            valid = (slots[None, :] <= idx[:, None]) | (posv >= s)
        else:
            valid = slots[None, :] <= posv

    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hk
    qg = q.reshape(b, hk, g, dh)
    sc = jnp.einsum("bhgd,bshd->bhgs", qg, kf).astype(jnp.float32) / dh ** 0.5
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    a = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgs,bshd->bhgd", a, vf).reshape(b, 1, h * dh)
    out = common.linear_apply(p["out"], o, specs.out, ctx)
    return out, {"k": k, "v": v}


def attn_prefill_chunk(p, x, cache, pos0, specs: AttnSpecs, cfg: ArchConfig,
                       ctx: ModelCtx, *, read_pages, write_pages, nreal):
    """Prefill one prompt *chunk* against the paged cache at a position offset.

    x: (B, C, D) — C chunk tokens (right-padded past `nreal`); pos0: (B,)
    absolute position of the chunk's first token; read_pages/write_pages:
    (B, max_pages) page rows. Token t sits at absolute position pos0+t, its
    KV is scattered to write_pages[(pos0+t)//P] offset (pos0+t)%P, and its
    query attends every pooled token at position <= pos0+t — the already-
    written prefix chunks plus this chunk's own tokens (scatter happens
    before the gather, so in-chunk causal attention reads through the pool).

    Two page rows because prefix sharing masks WRITES, not reads: a shared
    page already holds this prefix's KV (bytes are a pure function of the
    token prefix), so its write_pages entry is NULL_PAGE (scratch) while
    read_pages keeps the real id. Padding tokens (t >= nreal) are likewise
    redirected to scratch.

    Byte-exactness contract (tests/test_serving.py): this path must produce
    bit-identical KV to whole-prompt `attn_apply` prefill. It therefore
    mirrors `_gqa_scores_blockless` exactly — same einsum contractions, same
    masked softmax — relying on two XLA-CPU invariances the serving oracles
    already lean on: row-slicing a matmul and padding a masked key axis are
    both bit-exact. Requires the pool dtype == compute dtype (the int8 KV
    cache re-quantizes at chunk boundaries, which whole-prompt prefill does
    not — the server disables chunking there).
    """
    b, c, _ = x.shape
    y = common.linear_apply(p["qkv"], x, specs.qkv, ctx)
    q, k_new, v_new = _split_qkv(y, cfg)
    positions = (jnp.asarray(pos0, jnp.int32)[:, None]
                 + jnp.arange(c, dtype=jnp.int32)[None, :])          # (B, C)
    q = common.rope(q, positions, cfg.rope_theta)
    k_new = common.rope(k_new, positions, cfg.rope_theta)

    cd = cache["k"].dtype
    kq, vq = _kv_quant(k_new, cd), _kv_quant(v_new, cd)              # (B,C,Hk,dh)
    page_size = cache["k"].shape[1]
    rows = jnp.arange(b)
    tvalid = jnp.arange(c)[None, :] < jnp.asarray(nreal, jnp.int32)[:, None]
    pidx = jnp.minimum(positions // page_size, write_pages.shape[1] - 1)
    pid = jnp.where(tvalid, write_pages[rows[:, None], pidx], 0)     # NULL_PAGE
    off = positions % page_size
    k = cache["k"].at[pid, off].set(kq)
    v = cache["v"].at[pid, off].set(vq)

    s = read_pages.shape[1] * page_size
    kf = _kv_dequant(k[read_pages].reshape(b, s, *k.shape[2:]), x.dtype)
    vf = _kv_dequant(v[read_pages].reshape(b, s, *v.shape[2:]), x.dtype)
    valid = jnp.arange(s)[None, None, :] <= positions[:, :, None]    # (B, C, S)

    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hk
    qg = q.reshape(b, c, hk, g, dh)
    sc = jnp.einsum("bthgd,bshd->bhgts", qg, kf).astype(jnp.float32) / dh ** 0.5
    sc = jnp.where(valid[:, None, None, :, :], sc, NEG_INF)
    a = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgts,bshd->bthgd", a, vf).reshape(b, c, h * dh)
    out = common.linear_apply(p["out"], o, specs.out, ctx)
    return out, {"k": k, "v": v}


# -- cross attention (whisper decoder) ----------------------------------------

def cross_attn_apply(p, x, enc_kv, specs: AttnSpecs, cfg: ArchConfig, ctx: ModelCtx):
    """x: (B, T, D); enc_kv: precomputed (k, v) from the encoder output."""
    b, t, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = common.linear_apply(p["cross_q"], x, specs.cross_q, ctx).reshape(b, t, h, dh)
    k, v = enc_kv
    o = blockwise_attention(q, k, v, causal=False)
    return common.linear_apply(p["out"], o.reshape(b, t, -1), specs.out, ctx)


def cross_kv(p, enc_out, specs: AttnSpecs, cfg: ArchConfig, ctx: ModelCtx):
    b, s, _ = enc_out.shape
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    kv = common.linear_apply(p["cross_kv"], enc_out, specs.cross_kv, ctx)
    k, v = jnp.split(kv, 2, axis=-1)
    return k.reshape(b, s, hk, dh), v.reshape(b, s, hk, dh)
