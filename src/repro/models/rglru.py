"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: in-proj -> {x-branch: causal conv1d -> RG-LRU; gate branch: GeLU} ->
multiply -> out-proj. The RG-LRU recurrence

    r_t = sigmoid(W_r b_t + c_r),  i_t = sigmoid(W_i b_t + c_i)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * b_t)

is a diagonal linear recurrence -> computed with `jax.lax.associative_scan`
(log-depth, TPU-friendly — this is Griffin's own TPU strategy, so the
*baseline* here is already the parallel form; contrast with ssm.py's mLSTM).

Projections are QuantizedLinears; the recurrent state h stays fp32
(wide-accumulator rule, DESIGN.md §4). Decode carries (h, conv) — O(1)/token,
qualifying recurrentgemma for long_500k.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.precision import PrecisionPolicy

from . import common
from .common import ModelCtx

_C = 8.0  # Griffin's fixed recurrence sharpness


@dataclasses.dataclass(frozen=True)
class RGLRUSpecs:
    in_proj: Any          # D -> 2*Dr (x branch + gate branch)
    out: Any              # Dr -> D
    d_rnn: int


def rglru_specs(cfg: ArchConfig, pol: PrecisionPolicy, *, first=False, last=False) -> RGLRUSpecs:
    mk = lambda i, o: common.lspec(pol, "ssm_proj", i, o, first=first, last=last)
    return RGLRUSpecs(in_proj=mk(cfg.d_model, 2 * cfg.d_rnn),
                      out=mk(cfg.d_rnn, cfg.d_model), d_rnn=cfg.d_rnn)


def rglru_init(rng, cfg: ArchConfig, specs: RGLRUSpecs, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    dr = specs.d_rnn
    # Lambda init so that a ~ U[0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[3], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))          # softplus^-1(-log u / c)
    return {"in_proj": common.linear_init(ks[0], specs.in_proj, dtype),
            "conv": common.conv1d_init(ks[1], dr, 4, dtype),
            "w_gates": jax.random.normal(ks[2], (dr, 2), dtype) * 0.02,
            "lam": lam.astype(dtype),
            "out": common.linear_init(ks[4], specs.out, dtype)}


def rglru_state_shapes(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    return {"h": jax.ShapeDtypeStruct((batch, cfg.d_rnn), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, 3, cfg.d_rnn), dtype)}


def _gates(p, b):
    """b: (..., Dr) conv output -> (log_a, gated_in), elementwise gates."""
    bf = b.astype(jnp.float32)
    r = jax.nn.sigmoid(bf * p["w_gates"][:, 0])
    i = jax.nn.sigmoid(bf * p["w_gates"][:, 1])
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    u = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * bf)
    return log_a, u


def _pin(t, ctx):
    """Keep the RG-LRU channel dim sharded over the model axis through the
    associative scan (§Perf A iter-2: GSPMD loses the propagated sharding in
    the scan's slice/concat tree and falls back to permute/all-reduce churn —
    pinning (B, T, Dr~model) makes the scan fully local)."""
    return common.shard_spec(t, ctx, None, "model")


def rglru_apply(p, x, specs: RGLRUSpecs, ctx: ModelCtx):
    """Full-sequence (train/prefill): associative scan over time."""
    z = common.linear_apply(p["in_proj"], x, specs.in_proj, ctx)
    xb, gate = jnp.split(z, 2, axis=-1)
    xc, _ = common.conv1d_apply(p["conv"], xb)
    log_a, u = _gates(p, _pin(xc, ctx))                          # (B,T,Dr) f32

    def combine(c1, c2):
        (la1, u1), (la2, u2) = c1, c2
        return la1 + la2, u1 * jnp.exp(la2) + u2

    _, h = jax.lax.associative_scan(combine, (_pin(log_a, ctx), _pin(u, ctx)),
                                    axis=1)
    out = _pin(h, ctx).astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    return common.linear_apply(p["out"], out, specs.out, ctx)


def rglru_prefill(p, x, specs: RGLRUSpecs, ctx: ModelCtx):
    """Full-sequence prefill returning the decode state — PARALLEL form.

    §Perf optimization A (EXPERIMENTS.md): the baseline `_recurrent_prefill`
    stepped the decode cell sequentially over T (32k state round-trips,
    1132 s memory term); the associative scan already produces every h_t, so
    the final state is h[:, -1] and the conv state is the last width-1 raw
    inputs — same math, log-depth, ~600x less state traffic.
    """
    z = common.linear_apply(p["in_proj"], x, specs.in_proj, ctx)
    xb, gate = jnp.split(z, 2, axis=-1)
    xc, conv_state = common.conv1d_apply(p["conv"], xb)
    log_a, u = _gates(p, _pin(xc, ctx))

    def combine(c1, c2):
        (la1, u1), (la2, u2) = c1, c2
        return la1 + la2, u1 * jnp.exp(la2) + u2

    _, h = jax.lax.associative_scan(combine, (_pin(log_a, ctx), _pin(u, ctx)),
                                    axis=1)
    out = _pin(h, ctx).astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    y = common.linear_apply(p["out"], out, specs.out, ctx)
    return y, {"h": h[:, -1], "conv": conv_state}


def rglru_decode(p, x, state, specs: RGLRUSpecs, ctx: ModelCtx):
    """One-token decode. x: (B,1,D); state: {h (B,Dr) f32, conv}."""
    z = common.linear_apply(p["in_proj"], x, specs.in_proj, ctx)
    xb, gate = jnp.split(z, 2, axis=-1)
    xc, conv_state = common.conv1d_apply(p["conv"], xb, state["conv"])
    log_a, u = _gates(p, xc[:, 0])
    h = state["h"] * jnp.exp(log_a) + u
    out = h[:, None].astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    y = common.linear_apply(p["out"], out, specs.out, ctx)
    return y, {"h": h, "conv": conv_state}
