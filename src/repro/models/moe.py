"""Mixture-of-Experts with GShard-style capacity dispatch + expert parallelism.

Supports phi3.5-moe (16 experts, top-2) and deepseek-moe (2 shared + 64
routed, top-6, fine-grained d_ff). Expert FFN weights carry a leading expert
axis that `launch/sharding.py` places on the "model" mesh axis (EP); the
dispatch/combine einsums then lower to all-to-alls — the collective-bound
cell of the roofline study.

Router stays fp32 and unquantized (core.precision.ALWAYS_WIDE): it is tiny
and accuracy-critical — BrainTTA's "sensitive layers stay wide" rule.

Dispatch uses the dense (B,S,E,C) one-hot formulation: static shapes (SPMD-
friendly), with token dropping at capacity. Sort-based ragged dispatch is the
documented beyond-paper alternative (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.precision import PrecisionPolicy

from . import common, ffn
from .common import ModelCtx


@dataclasses.dataclass(frozen=True)
class MoESpecs:
    router: Any
    up: Any
    down: Any
    shared: Any            # FFNSpecs | None
    n_experts: int
    top_k: int
    capacity_factor: float
    gated: bool
    act: str


def moe_specs(cfg: ArchConfig, pol: PrecisionPolicy, *, first=False, last=False) -> MoESpecs:
    e, f, d = cfg.n_experts, cfg.d_ff, cfg.d_model
    up_out = 2 * f if cfg.gated_ffn else f
    # serve TP: Megatron pairing *within* each expert — the expert axis stays
    # unsharded (leading None in the shard_map specs) while each expert's
    # up/down shard N / packed-K over the model axis; the row-parallel psum
    # covers the whole expert stack in one collective (dispatch/combine
    # einsums stay global). The router is tiny and replicated.
    return MoESpecs(
        router=common.lspec(pol, "moe_router", d, e),
        up=common.lspec(pol, "moe_expert", d, up_out, first=first, last=last,
                        experts=e, parallel="column"),
        down=common.lspec(pol, "moe_expert", f, d, first=first, last=last,
                          experts=e, parallel="row"),
        shared=(ffn.ffn_specs(cfg, pol, first=first, last=last,
                              d_ff=cfg.n_shared_experts * f)
                if cfg.n_shared_experts else None),
        n_experts=e, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        gated=cfg.gated_ffn, act=cfg.act_fn,
    )


def moe_init(rng, specs: MoESpecs, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p = {"router": common.linear_init(ks[0], specs.router, dtype),
         "up": common.linear_init(ks[1], specs.up, dtype),
         "down": common.linear_init(ks[2], specs.down, dtype)}
    if specs.shared is not None:
        p["shared"] = ffn.ffn_init(ks[3], specs.shared, dtype)
    return p


def _capacity(s: int, specs: MoESpecs) -> int:
    c = int(s * specs.top_k / specs.n_experts * specs.capacity_factor)
    return max(4, (c + 3) // 4 * 4)


def moe_apply(p, x, specs: MoESpecs, ctx: ModelCtx):
    """x: (B, S, D) -> (B, S, D). Dense-dispatch MoE with top-k routing."""
    b, s, d = x.shape
    e, k = specs.n_experts, specs.top_k
    c = _capacity(s, specs)

    logits = common.linear_apply(p["router"], x, specs.router, ctx).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                      # (B,S,E)
    topv, topi = jax.lax.top_k(gates, k)                         # (B,S,K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    sel = jax.nn.one_hot(topi, e, dtype=jnp.float32)             # (B,S,K,E)
    flat = sel.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                        # slots before me
    pos = jnp.sum(flat * pos, axis=-1).reshape(b, s, k).astype(jnp.int32)  # (B,S,K)
    keep = (pos < c).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, c, dtype=jnp.float32)           # (B,S,K,C)

    # dispatch[b,s,e,c] / combine[b,s,e,c]
    dispatch = jnp.einsum("bske,bskc->bsec", sel * keep[..., None], pos_oh)
    combine = jnp.einsum("bske,bskc->bsec", sel * (topv * keep)[..., None], pos_oh)

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(x.dtype), x)  # (E,B,C,D)
    xin = xin.reshape(e, b * c, d)
    h = common.linear_apply(p["up"], xin, specs.up, ctx)
    act = common.activation(specs.act)
    if specs.gated:
        g, u = jnp.split(h, 2, axis=-1)
        h = act(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = act(h.astype(jnp.float32)).astype(x.dtype)
    h = common.linear_apply(p["down"], h, specs.down, ctx)
    h = h.reshape(e, b, c, d)
    y = jnp.einsum("ebcd,bsec->bsd", h, combine.astype(x.dtype))

    if specs.shared is not None:
        y = y + ffn.ffn_apply(p["shared"], x, specs.shared, ctx)

    # aux load-balancing loss term (Switch-style), returned via metric side-car
    density = jnp.mean(jnp.sum(sel, axis=2), axis=(0, 1))        # (E,) token frac
    router_prob = jnp.mean(gates, axis=(0, 1))                   # (E,)
    aux = e * jnp.sum(density * router_prob)
    return y, aux
