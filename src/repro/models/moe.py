"""Mixture-of-Experts with GShard-style capacity dispatch + expert parallelism.

Supports phi3.5-moe (16 experts, top-2) and deepseek-moe (2 shared + 64
routed, top-6, fine-grained d_ff). Expert FFN weights carry a leading expert
axis that `launch/sharding.py` places on the "model" mesh axis (EP); under a
serve mesh with `ctx.ep` set, the expert qgemms run the grouped expert
dispatch (`kernels.dispatch._ep_column`/`_ep_row`) — each shard computes
only its local experts on their capacity-dispatched token slabs, with one
psum assembling the down projection (see docs/MOE.md).

Router stays fp32 and unquantized (core.precision.ALWAYS_WIDE): it is tiny
and accuracy-critical — BrainTTA's "sensitive layers stay wide" rule. It is
also REPLICATED under EP: every shard routes identically, which is what
makes capacity drops deterministic and shard-count independent.

Dispatch uses the dense (B,S,E,C) one-hot formulation: static shapes (SPMD-
friendly), with token dropping at capacity. Sort-based ragged dispatch is the
documented beyond-paper alternative (EXPERIMENTS.md §Perf).

Determinism contract (the token-exact-vs-oracle bar): `jax.lax.top_k`
breaks gate ties toward the lowest expert index, and capacity slots are
assigned by flat (s*k) cumsum position — both pure functions of the gate
values, no RNG, no device-count dependence. EP serving therefore drops
exactly the tokens the single-device dense-vmap oracle drops, and
`tests/test_moe_serving.py` holds the outputs bit-equal.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.precision import PrecisionPolicy

from . import common, ffn
from .common import ModelCtx


@dataclasses.dataclass(frozen=True)
class MoESpecs:
    router: Any
    up: Any
    down: Any
    shared: Any            # FFNSpecs | None
    n_experts: int
    top_k: int
    capacity_factor: float
    gated: bool
    act: str


def moe_specs(cfg: ArchConfig, pol: PrecisionPolicy, *, first=False, last=False) -> MoESpecs:
    e, f, d = cfg.n_experts, cfg.d_ff, cfg.d_model
    up_out = 2 * f if cfg.gated_ffn else f
    # serve meshes: the parallel= markers feed BOTH plans. EP (preferred,
    # ctx.ep) shards the leading expert axis — column runs local experts
    # with no collective, row assembles with one disjoint psum. When ep_plan
    # declines (E % shards != 0), the same markers drive Megatron pairing
    # *within* each expert — expert axis unsharded, each expert's up/down
    # sharding N / packed-K, one row psum over the whole stack (dispatch/
    # combine einsums stay global). The router is tiny and replicated.
    return MoESpecs(
        router=common.lspec(pol, "moe_router", d, e),
        up=common.lspec(pol, "moe_expert", d, up_out, first=first, last=last,
                        experts=e, parallel="column"),
        down=common.lspec(pol, "moe_expert", f, d, first=first, last=last,
                          experts=e, parallel="row"),
        shared=(ffn.ffn_specs(cfg, pol, first=first, last=last,
                              d_ff=cfg.n_shared_experts * f)
                if cfg.n_shared_experts else None),
        n_experts=e, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        gated=cfg.gated_ffn, act=cfg.act_fn,
    )


def moe_init(rng, specs: MoESpecs, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p = {"router": common.linear_init(ks[0], specs.router, dtype),
         "up": common.linear_init(ks[1], specs.up, dtype),
         "down": common.linear_init(ks[2], specs.down, dtype)}
    if specs.shared is not None:
        p["shared"] = ffn.ffn_init(ks[3], specs.shared, dtype)
    return p


def _capacity(s: int, specs: MoESpecs) -> int:
    c = int(s * specs.top_k / specs.n_experts * specs.capacity_factor)
    return max(4, (c + 3) // 4 * 4)


def moe_apply(p, x, specs: MoESpecs, ctx: ModelCtx):
    """x: (B, S, D) -> (B, S, D). Dense-dispatch MoE with top-k routing.

    Returns (y, aux) where aux is a dict:
      "loss"          — scalar Switch-style load-balancing term (train)
      "expert_tokens" — (E,) int32, tokens·top-k assignments that landed a
                        capacity slot on each expert this call (utilization)
      "dropped"       — int32, assignments past capacity (dropped this call)
    The counters are exact under EP because routing is replicated; the
    serve driver accumulates them into `Server.stats` when ctx.moe_stats.
    """
    b, s, d = x.shape
    e, k = specs.n_experts, specs.top_k
    c = _capacity(s, specs)

    logits = common.linear_apply(p["router"], x, specs.router, ctx).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                      # (B,S,E)
    topv, topi = jax.lax.top_k(gates, k)                         # (B,S,K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    sel = jax.nn.one_hot(topi, e, dtype=jnp.float32)             # (B,S,K,E)
    flat = sel.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                        # slots before me
    pos = jnp.sum(flat * pos, axis=-1).reshape(b, s, k).astype(jnp.int32)  # (B,S,K)
    keep = (pos < c).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, c, dtype=jnp.float32)           # (B,S,K,C)

    # dispatch[b,s,e,c] / combine[b,s,e,c]
    dispatch = jnp.einsum("bske,bskc->bsec", sel * keep[..., None], pos_oh)
    combine = jnp.einsum("bske,bskc->bsec", sel * (topv * keep)[..., None], pos_oh)

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(x.dtype), x)  # (E,B,C,D)
    xin = xin.reshape(e, b * c, d)
    h = common.linear_apply(p["up"], xin, specs.up, ctx)
    act = common.activation(specs.act)
    if specs.gated:
        g, u = jnp.split(h, 2, axis=-1)
        h = act(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = act(h.astype(jnp.float32)).astype(x.dtype)
    h = common.linear_apply(p["down"], h, specs.down, ctx)
    h = h.reshape(e, b, c, d)
    y = jnp.einsum("ebcd,bsec->bsd", h, combine.astype(x.dtype))

    if specs.shared is not None:
        y = y + ffn.ffn_apply(p["shared"], x, specs.shared, ctx)

    # aux side-car: load-balancing loss term (Switch-style) + routing stats
    density = jnp.mean(jnp.sum(sel, axis=2), axis=(0, 1))        # (E,) token frac
    router_prob = jnp.mean(gates, axis=(0, 1))                   # (E,)
    kept = sel * keep[..., None]                                 # (B,S,K,E)
    aux = {
        "loss": e * jnp.sum(density * router_prob),
        "expert_tokens": jnp.sum(kept, axis=(0, 1, 2)).astype(jnp.int32),
        "dropped": jnp.sum(1.0 - keep).astype(jnp.int32),
    }
    return y, aux
