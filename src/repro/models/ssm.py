"""xLSTM blocks (sLSTM + mLSTM) [arXiv:2405.04517] — the [ssm] architecture.

Both blocks use exponential gating with the max-state stabilizer. The
projections (in/out/gates/qkv) are QuantizedLinears ("ssm_proj" layer class);
the recurrent state itself stays fp32 — it is the wide accumulator in
BrainTTA terms, requantized only at block egress (DESIGN.md §4).

Training/prefill runs a `lax.scan` over time (the paper-faithful sequential
baseline; the chunkwise-parallel mLSTM is a §Perf hillclimb candidate).
Decode carries (c, n, m) / (C, n, m) state — O(1) per token, which is what
qualifies xlstm for the long_500k shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.precision import PrecisionPolicy

from . import common
from .common import ModelCtx


# ---------------------------------------------------------------------------
# mLSTM: matrix memory C (dh x dh) per head
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLSTMSpecs:
    in_proj: Any          # D -> 2*Di (x branch, output gate branch)
    qkv: Any              # Di -> 3*Di
    gates: Any            # Di -> 2*H  (i, f pre-activations per head)
    out: Any              # Di -> D
    d_inner: int
    n_heads: int


def mlstm_specs(cfg: ArchConfig, pol: PrecisionPolicy, *, first=False, last=False) -> MLSTMSpecs:
    di = 2 * cfg.d_model
    mk = lambda i, o: common.lspec(pol, "ssm_proj", i, o, first=first, last=last)
    return MLSTMSpecs(in_proj=mk(cfg.d_model, 2 * di), qkv=mk(di, 3 * di),
                      gates=mk(di, 2 * cfg.n_heads), out=mk(di, cfg.d_model),
                      d_inner=di, n_heads=cfg.n_heads)


def mlstm_init(rng, cfg: ArchConfig, specs: MLSTMSpecs, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    return {"in_proj": common.linear_init(ks[0], specs.in_proj, dtype),
            "conv": common.conv1d_init(ks[1], specs.d_inner, 4, dtype),
            "qkv": common.linear_init(ks[2], specs.qkv, dtype),
            "gates": common.linear_init(ks[3], specs.gates, dtype),
            "out": common.linear_init(ks[4], specs.out, dtype)}


def _mlstm_cell(state, inp):
    """One step. state: (C (B,H,dk,dv), n (B,H,dk), m (B,H)).
    inp: q,k,v (B,H,dh), i_pre,f_pre (B,H)."""
    C, n, m = state
    q, k, v, i_pre, f_pre = inp
    log_f = -jax.nn.softplus(-f_pre)                 # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    C = f_g[..., None, None] * C + i_g[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = f_g[..., None] * n + i_g[..., None] * k
    h_num = jnp.einsum("bhkv,bhk->bhv", C, q)
    h_den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q))
    h = h_num / jnp.maximum(h_den, 1.0)[..., None]
    return (C, n, m_new), h


def mlstm_state_shapes(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    di = 2 * cfg.d_model
    h, dh = cfg.n_heads, di // cfg.n_heads
    f32 = jnp.float32
    return {"C": jax.ShapeDtypeStruct((batch, h, dh, dh), f32),
            "n": jax.ShapeDtypeStruct((batch, h, dh), f32),
            "m": jax.ShapeDtypeStruct((batch, h), f32),
            "conv": jax.ShapeDtypeStruct((batch, 3, di), dtype)}


def _mlstm_inputs(p, x, specs: MLSTMSpecs, ctx: ModelCtx, conv_state=None):
    b, t, _ = x.shape
    h = specs.n_heads
    di = specs.d_inner
    dh = di // h
    z = common.linear_apply(p["in_proj"], x, specs.in_proj, ctx)
    xi, og = jnp.split(z, 2, axis=-1)
    xc, conv_state = common.conv1d_apply(p["conv"], xi, conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    qkv = common.linear_apply(p["qkv"], xc, specs.qkv, ctx)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    gates = common.linear_apply(p["gates"], xc, specs.gates, ctx).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)                  # (B,T,H)
    rs = lambda a: a.reshape(b, t, h, dh).astype(jnp.float32)
    return rs(q) / dh ** 0.5, rs(k) / dh ** 0.5, rs(v), i_pre, f_pre, og, conv_state


def mlstm_apply(p, x, specs: MLSTMSpecs, ctx: ModelCtx, impl: str = "scan",
                chunk: int = 64):
    """Full-sequence mLSTM (train/prefill).

    impl="scan"      paper-faithful sequential cell (one (dh x dh) state
                     read+write per token — the xlstm train_4k cell's 889 s
                     memory term comes from exactly this).
    impl="chunkwise" §Perf (beyond paper): flash-linear-attention-style
                     chunking — intra-chunk contributions are masked matmuls,
                     the matrix state C updates once per chunk. State traffic
                     /chunk, MXU-friendly; validated against the sequential
                     oracle (tests/test_mlstm_chunkwise.py).
    """
    b, t, _ = x.shape
    h, di = specs.n_heads, specs.d_inner
    dh = di // h
    q, k, v, i_pre, f_pre, og, _ = _mlstm_inputs(p, x, specs, ctx)
    if impl == "chunkwise" and t % chunk == 0 and t > chunk:
        hs, _ = _mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk)
    else:
        tfirst = lambda a: jnp.moveaxis(a, 1, 0)
        init = (jnp.zeros((b, h, dh, dh), jnp.float32),
                jnp.zeros((b, h, dh), jnp.float32),
                jnp.full((b, h), -1e30, jnp.float32))
        _, hs = jax.lax.scan(_mlstm_cell, init,
                             tuple(map(tfirst, (q, k, v, i_pre, f_pre))))
        hs = jnp.moveaxis(hs, 0, 1)
    hs = hs.reshape(b, t, di).astype(x.dtype)
    out = hs * jax.nn.silu(og.astype(jnp.float32)).astype(x.dtype)
    return common.linear_apply(p["out"], out, specs.out, ctx)


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk: int):
    """Chunkwise-parallel mLSTM forward. q,k,v: (B,T,H,dh) f32 (already
    scaled); i_pre,f_pre: (B,T,H). Returns h: (B,T,H,dh).

    Math per chunk (log-space stabilized like the sequential cell):
        LF_t  = cumsum(log f)                (within chunk)
        C_t   = F_t C_0 + sum_{j<=t} (F_t/F_j) i_j k_j v_j^T
        num_t = F_t (q_t C_0) + sum_{j<=t} (F_t/F_j) i_j (q_t.k_j) v_j
        den_t = same with v_j -> 1 (the n-state dot)
    Stabilizer: the carried state (C_0, n_0) is stored scaled by exp(-m_0);
    within a chunk every term is scaled by exp(-m_t) with
    m_t = max(m_0 + LF_t, max_j(LF_t - LF_j + i_pre_j)) — the same max the
    sequential cell tracks, evaluated blockwise.
    """
    b, t, h, dh = q.shape
    nc = t // chunk
    cs = lambda a, d: jnp.moveaxis(a.reshape(b, nc, chunk, *a.shape[2:]), 1, 0)
    qc, kc, vc = cs(q, 4), cs(k, 4), cs(v, 4)          # (nc, B, c, H, dh)
    ic, fc = cs(i_pre, 3), cs(f_pre, 3)                # (nc, B, c, H)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))      # j <= t

    def chunk_step(carry, inp):
        C0, n0, m0 = carry              # scaled by exp(-m0); (B,H,dh,dh) etc.
        qb, kb, vb, ib, fb = inp        # (B, c, H, dh|)
        log_f = -jax.nn.softplus(-fb)                   # (B, c, H)
        lf = jnp.cumsum(log_f, axis=1)                  # LF_t
        # stabilizer per position: candidates from carry and intra terms
        intra_log = (lf[:, :, None, :] - lf[:, None, :, :]
                     + ib[:, None, :, :])               # (B, t, j, H)
        intra_log = jnp.where(tri[None, :, :, None], intra_log, -jnp.inf)
        m_intra = jnp.max(intra_log, axis=2)            # (B, c, H)
        m_t = jnp.maximum(m0[:, None] + lf, m_intra)    # (B, c, H)

        # decay matrices
        d_intra = jnp.exp(intra_log - m_t[:, :, None, :])   # (B, t, j, H)
        d_inter = jnp.exp(m0[:, None] + lf - m_t)           # (B, c, H)

        # scores (B, t, j, H): q_t . k_j per head
        s = jnp.einsum("bthd,bjhd->btjh", qb, kb) * d_intra
        num = (jnp.einsum("btjh,bjhd->bthd", s, vb)
               + d_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qb, C0))
        den = (jnp.sum(s, axis=2)
               + d_inter * jnp.einsum("bthd,bhd->bth", qb, n0))
        # oracle semantics: max(|n.q|, 1) on the exp(-m_t)-scaled value, and
        # our blockwise m_t == the sequential running max (see docstring)
        hb = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # state update to end of chunk (scale exp(-m_new))
        lf_tot = lf[:, -1]                               # (B, H)
        m_new = jnp.maximum(m0 + lf_tot,
                            jnp.max(lf_tot[:, None] - lf + ib, axis=1))
        w_j = jnp.exp(lf_tot[:, None] - lf + ib - m_new[:, None])  # (B, c, H)
        C_new = (jnp.exp(m0 + lf_tot - m_new)[:, :, None, None] * C0
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", w_j, kb, vb))
        n_new = (jnp.exp(m0 + lf_tot - m_new)[:, :, None] * n0
                 + jnp.einsum("bjh,bjhd->bhd", w_j, kb))
        return (C_new, n_new, m_new), hb

    C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    state, hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, ic, fc))
    return jnp.moveaxis(hs, 0, 1).reshape(b, nc * chunk, h, dh), state


def mlstm_prefill(p, x, specs: MLSTMSpecs, ctx: ModelCtx, chunk: int = 64):
    """Prefill returning the decode state via the chunkwise pass (§Perf:
    the sequential-stepping prefill cost 98 s memory term on 32k; the
    chunkwise pass computes the same (C, n, m) final state /chunk cheaper).
    Falls back to None when T doesn't chunk (caller uses the sequential path).
    """
    b, t, _ = x.shape
    if t % chunk or t <= chunk:
        return None
    q, k, v, i_pre, f_pre, og, conv_state = _mlstm_inputs(p, x, specs, ctx)
    hs, (C, n, m) = _mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk)
    hs = hs.reshape(b, t, specs.d_inner).astype(x.dtype)
    out = hs * jax.nn.silu(og.astype(jnp.float32)).astype(x.dtype)
    y = common.linear_apply(p["out"], out, specs.out, ctx)
    return y, {"C": C, "n": n, "m": m, "conv": conv_state}


def mlstm_decode(p, x, state, specs: MLSTMSpecs, ctx: ModelCtx):
    """One-token decode. x: (B,1,D); state: {C,n,m,conv}."""
    b = x.shape[0]
    q, k, v, i_pre, f_pre, og, conv_state = _mlstm_inputs(
        p, x, specs, ctx, conv_state=state["conv"])
    st = (state["C"], state["n"], state["m"])
    sq = lambda a: a[:, 0]
    st, h = _mlstm_cell(st, (sq(q), sq(k), sq(v), sq(i_pre), sq(f_pre)))
    h = h.reshape(b, 1, specs.d_inner).astype(x.dtype)
    out = h * jax.nn.silu(og.astype(jnp.float32)).astype(x.dtype)
    y = common.linear_apply(p["out"], out, specs.out, ctx)
    return y, {"C": st[0], "n": st[1], "m": st[2], "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM: scalar memory per head-dim channel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLSTMSpecs:
    gates: Any            # D -> 4*D (i, f, z, o pre-acts)
    rec: Any              # per-head recurrent weights (H, dh, 4*dh), non-QLinear
    out: Any              # D -> D
    n_heads: int
    d_model: int


def slstm_specs(cfg: ArchConfig, pol: PrecisionPolicy, *, first=False, last=False) -> SLSTMSpecs:
    mk = lambda i, o: common.lspec(pol, "ssm_proj", i, o, first=first, last=last)
    return SLSTMSpecs(gates=mk(cfg.d_model, 4 * cfg.d_model), rec=None,
                      out=mk(cfg.d_model, cfg.d_model),
                      n_heads=cfg.n_heads, d_model=cfg.d_model)


def slstm_init(rng, cfg: ArchConfig, specs: SLSTMSpecs, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    h = specs.n_heads
    dh = specs.d_model // h
    return {"gates": common.linear_init(k1, specs.gates, dtype),
            "rec": jax.random.normal(k2, (h, dh, 4 * dh), dtype) * (0.3 / dh ** 0.5),
            "out": common.linear_init(k3, specs.out, dtype)}


def slstm_state_shapes(cfg: ArchConfig, batch: int):
    f32 = jnp.float32
    d = cfg.d_model
    return {"c": jax.ShapeDtypeStruct((batch, d), f32),
            "n": jax.ShapeDtypeStruct((batch, d), f32),
            "m": jax.ShapeDtypeStruct((batch, d), f32),
            "h": jax.ShapeDtypeStruct((batch, d), f32)}


def _slstm_cell(p_rec, n_heads, state, g_pre):
    """state: c,n,m,h each (B,D); g_pre: (B,4D) pre-activations from x."""
    c, n, m, h_prev = state
    b, d = c.shape
    dh = d // n_heads
    hh = h_prev.reshape(b, n_heads, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, p_rec.astype(h_prev.dtype))  # (B,H,4dh)
    g = g_pre + rec.reshape(b, 4 * d)
    i_pre, f_pre, z_pre, o_pre = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_pre)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_apply(p, x, specs: SLSTMSpecs, ctx: ModelCtx):
    b, t, d = x.shape
    g_pre = common.linear_apply(p["gates"], x, specs.gates, ctx).astype(jnp.float32)
    init = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) + (
        jnp.zeros((b, d), jnp.float32),)
    init = (init[0], init[1], jnp.full((b, d), -1e30, jnp.float32), init[3])
    cell = lambda st, g: _slstm_cell(p["rec"], specs.n_heads, st, g)
    _, hs = jax.lax.scan(cell, init, jnp.moveaxis(g_pre, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    return common.linear_apply(p["out"], hs, specs.out, ctx)


def slstm_decode(p, x, state, specs: SLSTMSpecs, ctx: ModelCtx):
    g_pre = common.linear_apply(p["gates"], x, specs.gates, ctx).astype(jnp.float32)
    st = (state["c"], state["n"], state["m"], state["h"])
    st, h = _slstm_cell(p["rec"], specs.n_heads, st, g_pre[:, 0])
    y = common.linear_apply(p["out"], h[:, None].astype(x.dtype), specs.out, ctx)
    return y, {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}
