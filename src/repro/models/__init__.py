"""Model zoo: composable blocks (attention/ffn/moe/ssm/rglru) + assemblies
for all 10 assigned architectures, with QuantizedLinear everywhere a GEMM
lives. See registry.build / registry.input_specs."""
from . import attention, common, ffn, moe, registry, rglru, ssm, transformer  # noqa: F401
