"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain (squared-ReLU, nemotron).

Both up and down projections are QuantizedLinears (BrainTTA layer types 1/5);
the activation between them runs wide, requantization happens at the next
linear's ingress (§IV-B "requantize as early as possible" maps to: the narrow
format is the *storage/transport* format, the nonlinearity runs on the wide
accumulator before requant).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.precision import PrecisionPolicy

from . import common
from .common import ModelCtx


@dataclasses.dataclass(frozen=True)
class FFNSpecs:
    up: Any
    down: Any
    gated: bool
    act: str


def ffn_specs(cfg: ArchConfig, pol: PrecisionPolicy, *, first=False, last=False,
              d_ff: int = 0) -> FFNSpecs:
    # serve TP (Megatron pairing): up is column-parallel (hidden dim sharded,
    # no collective), down is row-parallel (packed-K sharded, one
    # pre-requant int32 psum per block)
    f = d_ff or cfg.d_ff
    up_out = 2 * f if cfg.gated_ffn else f
    return FFNSpecs(
        up=common.lspec(pol, "ffn_up", cfg.d_model, up_out, first=first,
                        last=last, parallel="column"),
        down=common.lspec(pol, "ffn_down", f, cfg.d_model, first=first,
                          last=last, parallel="row"),
        gated=cfg.gated_ffn,
        act=cfg.act_fn,
    )


def ffn_init(rng, specs: FFNSpecs, dtype=jnp.float32):
    k1, k2 = jax.random.split(rng)
    return {"up": common.linear_init(k1, specs.up, dtype),
            "down": common.linear_init(k2, specs.down, dtype)}


def ffn_apply(p, x, specs: FFNSpecs, ctx: ModelCtx):
    h = common.linear_apply(p["up"], x, specs.up, ctx)
    act = common.activation(specs.act)
    if specs.gated:
        g, u = jnp.split(h, 2, axis=-1)
        h = act(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = act(h.astype(jnp.float32)).astype(x.dtype)
    return common.linear_apply(p["down"], h, specs.down, ctx)
