"""Shared model components: norms, RoPE, activations, embeddings, conv1d,
and the quantized-linear helpers every block builds on.

All modules are functional: `*_init(rng, ...) -> params`, `*_apply(params, ...)`.
Compute dtype is bf16, norms/softmax/router in f32 (the "wide residual
stream" — BrainTTA keeps accumulators wide and requantizes at operator
egress, §IV-B).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlinear
from repro.core.precision import LayerQuant, PrecisionPolicy
from repro.core.qlinear import QLinearSpec


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Execution context threaded through every block."""
    mode: str = "train"          # "train" (QAT) | "serve" (packed)
    backend: str = "jnp"         # "jnp" | "pallas"
    impl: str = "popcount"       # binary/ternary GEMM formulation
    dtype: jnp.dtype = jnp.bfloat16
    act_dp: tuple | None = None  # dp mesh axes to pin activations' batch dim to
    attn_cp: str | None = None   # mesh axis for context-parallel attention
                                 # (q sequence sharded; kv replicated per dp
                                 # group — head-count agnostic, unlike head-TP)
    fsdp_wire: str = "dense"     # "packed": FSDP gathers move the 1/2/8-bit
                                 # planes instead of bf16 weights (§Perf B)
    tp: object | None = None     # kernels.dispatch.TPSpec: serve-mode tensor
                                 # parallelism — qgemm runs under shard_map in
                                 # each layer's spec.parallel role (set by the
                                 # --mesh serving driver; None everywhere else)
    tune: object | None = None   # kernels.dispatch.TuneTable override: per-
                                 # cell Tile choices (None = the shipped CPU
                                 # default table inside dispatch)
    paged_attn: str = "auto"     # paged decode-attention path: "auto" (fused
                                 # Pallas kernel iff backend == "pallas"),
                                 # "fused" (force the kernel), "gather"
                                 # (force the jnp oracle path)
    draft_planes: int | None = None  # self-speculative DRAFT context: layers
                                 # resolving to a plane-composed cell contract
                                 # only the leading N MSB planes (clamped to
                                 # the cell's stack depth); other layers run
                                 # full precision. None everywhere but the
                                 # serve driver's draft pass.
    ep: object | None = None     # kernels.dispatch.EPSpec: serve-mode expert
                                 # parallelism — expert-stacked qgemms run the
                                 # grouped dispatch (each shard computes only
                                 # its local experts) instead of the
                                 # replicated dense vmap. Set by the --mesh
                                 # serving driver for MoE archs; None
                                 # everywhere else.
    moe_stats: bool = False      # surface per-step MoE routing stats: the
                                 # top-level serve entry points return a third
                                 # {"expert_tokens": (E,) i32, "dropped": i32}
                                 # value summed over MoE blocks (Server.stats
                                 # feeds on it). Off => 2-tuple returns, so
                                 # non-MoE callers and lowering probes keep
                                 # their shapes.


TRAIN = ModelCtx(mode="train")


def shard_act(x, ctx: "ModelCtx"):
    """Pin a (B, ...) activation's batch dim to the dp axes. Without this,
    GSPMD can resolve the FSDP/TP weight shardings by replicating the batch —
    catastrophic activation all-gathers (seen: 32 GiB logit gathers)."""
    if ctx.act_dp is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(ctx.act_dp), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def shard_spec(x, ctx: "ModelCtx", *dims):
    """with_sharding_constraint with explicit trailing dims (batch first)."""
    if ctx.act_dp is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(tuple(ctx.act_dp), *dims))


# NOTE (serve TP): there is deliberately no "pin this activation axis to the
# model mesh axis" helper for the serve path. Head sharding flows from the
# column-parallel qkv shard_map out_specs; an explicit
# with_sharding_constraint on the head axis made the CPU SPMD partitioner
# miscompile the blocked-attention scan (value-level divergence caught by
# tests/test_serving_tp.py's token-exact oracle). Let the shard_map
# boundaries dictate placement instead.


# -- linear helper ------------------------------------------------------------

def lspec(pol: PrecisionPolicy, layer_class: str, in_dim: int, out_dim: int, *,
          first: bool = False, last: bool = False, bias: bool = False,
          experts: int = 0, name: str = "",
          parallel: str = "none") -> QLinearSpec:
    lq = pol.lookup(layer_class, is_first=first, is_last=last)
    return QLinearSpec(in_dim, out_dim, lq, use_bias=bias, experts=experts,
                       name=name or layer_class, parallel=parallel)


def linear_init(rng, spec: QLinearSpec, dtype=jnp.float32):
    return qlinear.init(rng, spec, dtype)


def linear_apply(p, x, spec: QLinearSpec, ctx: ModelCtx):
    if ctx.mode == "serve":
        y = qlinear.apply(p, x, spec, mode="serve",
                          op=operating_point(spec, ctx), tp=ctx.tp,
                          ep=ctx.ep)
    else:
        y = qlinear.apply(p, x, spec, mode=ctx.mode, wire=ctx.fsdp_wire)
    return y.astype(ctx.dtype)


def operating_point(spec: QLinearSpec, ctx: ModelCtx):
    """Resolve THIS layer's `dispatch.OperatingPoint`: precisions from the
    layer's policy assignment (spec.lq), formulation/backend from the
    execution context, tile from the context's TuneTable when one is loaded
    (else qgemm falls back to the shipped default table). This per-layer
    resolution is what lets one policy serve heterogeneous operating points
    — e.g. s4 ffn_up next to ternary attn_out — with no global flag pair."""
    from repro.core import pack
    from repro.kernels import dispatch
    from repro.kernels.dispatch import OperatingPoint
    op = OperatingPoint.for_spec(spec, impl=ctx.impl, backend=ctx.backend)
    try:
        cell = dispatch.lookup(op)
    except KeyError:
        # impl fallback: a formulation only SOME pairs implement (e.g.
        # impl="planes" exists for int4/int8 x int8 only) resolves per layer
        # — pairs without it run their default cell instead of erroring, so
        # one --impl planes flag serves a heterogeneous policy end to end.
        op = dataclasses.replace(op, impl="popcount")
        cell = dispatch.lookup(op)
    if ctx.draft_planes is not None and "w_planes" in cell.weight_names:
        op = dataclasses.replace(
            op, planes=min(ctx.draft_planes, pack.PLANE_BITS[op.wprec]))
    if ctx.tune is not None:
        op = dataclasses.replace(op, tile=ctx.tune.tile_for(op))
    return op


def pack_linear(p, spec: QLinearSpec):
    return qlinear.pack_params(p, spec)


# -- norms --------------------------------------------------------------------

def norm_init(d: int, kind: str = "rmsnorm", dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_apply(p, x, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * inv * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(x.dtype)


# -- activations ----------------------------------------------------------------

def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":                      # nemotron-4
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# -- rotary embeddings ----------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply RoPE. x: (B, T, H, dh), positions: (B, T) or (T,)."""
    dh = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions.astype(jnp.float32)[..., None] * freqs        # (..., T, dh/2)
    if ang.ndim == 2:                                             # (T, dh/2)
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(t: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embedding table (T, D)."""
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -- embedding ------------------------------------------------------------------

def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    return {"w": jax.random.normal(rng, (vocab, d), dtype) * 0.02}


def embed_apply(p, tokens: jnp.ndarray, dtype=jnp.bfloat16):
    return jnp.take(p["w"], tokens, axis=0).astype(dtype)


# -- causal temporal conv (xLSTM / RG-LRU frontends) -----------------------------

def conv1d_init(rng, d: int, width: int = 4, dtype=jnp.float32):
    return {"w": jax.random.normal(rng, (width, d), dtype) * (1.0 / width ** 0.5),
            "b": jnp.zeros((d,), dtype)}


def conv1d_apply(p, x: jnp.ndarray, state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: (B, T, D). state: (B, width-1, D) for decode.

    Returns (y, new_state). Training: state=None -> zero left-pad.
    """
    w = p["w"].astype(x.dtype)
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)                      # (B, T+w-1, D)
    y = sum(xx[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    new_state = xx[:, -(width - 1):, :] if width > 1 else state
    return (y + p["b"].astype(x.dtype), new_state)


# -- serve-side token sampling ----------------------------------------------------

def sample_token(logits_row, temperature: float, seed: int, index: int) -> int:
    """Host-side next-token draw for the serving loop (and its test oracles).

    temperature <= 0 is greedy argmax. Otherwise a categorical draw from
    softmax(logits / T) using a STATELESS numpy rng keyed by (seed, index) —
    no mutable stream, so token `index` of a request reproduces bit-exactly
    no matter how the request was batched, preempted/resumed, or
    prefix-shared in between. That determinism is what lets the scheduler
    tests demand token-exact equality against a sequential oracle, and what
    makes copy-on-write observable at all: two requests sharing a prompt
    prefix diverge only through (seed, temperature).

    Runs on host float64 from the f32 logits — identical logits therefore
    always give identical tokens (argmax ties break to the lowest index on
    both np and jnp).
    """
    row = np.asarray(logits_row, np.float64).reshape(-1)
    if temperature <= 0.0:
        return int(np.argmax(row))
    z = row / float(temperature)
    z -= z.max()
    p = np.exp(z)
    p /= p.sum()
    rng = np.random.default_rng((int(seed) & 0x7FFFFFFF, int(index)))
    return int(rng.choice(row.shape[0], p=p))


# -- loss -------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token-level CE. logits: (B, T, V) any float dtype, targets: (B, T) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
