"""AdamW with cosine schedule, global-norm clipping, and optional
int8-quantized moments (blockwise, bitsandbytes-style).

The int8 moments are the paper's quantization idea applied to the optimizer:
m/v are stored as int8 codes + per-block fp32 absmax scales (block = 256
contiguous elements), cutting optimizer-state HBM 4x — material at 340B
(EXPERIMENTS.md §Dry-run memory analysis).

Functional API (optax-like):
    opt = adamw(lr_schedule, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


# -- blockwise int8 tensor codec ------------------------------------------------
#
# Codes keep the tensor's shape (int8); scales are per-block along the LAST
# axis. Shape preservation matters for distribution: the codes shard with the
# exact PartitionSpec of their parameter, so the optimizer update stays fully
# local — no resharding collectives (launch/sharding.py).

def _q8_encode(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """fp32 tensor -> (int8 codes, same shape; per-last-axis-block scales)."""
    last = x.shape[-1] if x.ndim else 1
    blk = min(BLOCK, last)
    pad = (-last) % blk
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    blocks = xp.reshape(*xp.shape[:-1], -1, blk)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    codes = codes.reshape(xp.shape)[..., :last]
    return codes, jnp.squeeze(scale, -1).astype(jnp.float32)


def _q8_decode(codes: jnp.ndarray, scale: jnp.ndarray, shape, size) -> jnp.ndarray:
    last = codes.shape[-1] if codes.ndim else 1
    blk = min(BLOCK, last)
    pad = (-last) % blk
    cp = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)]) if pad else codes
    blocks = cp.reshape(*cp.shape[:-1], -1, blk).astype(jnp.float32)
    out = blocks * scale[..., None]
    return out.reshape(cp.shape)[..., :last].reshape(shape)


@dataclasses.dataclass(frozen=True)
class Q8Tensor:
    codes: jnp.ndarray
    scale: jnp.ndarray

jax.tree_util.register_dataclass(Q8Tensor, data_fields=["codes", "scale"],
                                 meta_fields=[])


def _maybe_encode(x, int8: bool):
    return Q8Tensor(*_q8_encode(x)) if int8 else x


def _maybe_decode(t, like, int8: bool):
    return _q8_decode(t.codes, t.scale, like.shape, like.size) if int8 else t


# -- schedules -------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# -- AdamW ------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0, int8_state: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        def fresh():  # m and v must be distinct buffers (donation aliases)
            return jax.tree.map(
                lambda p: _maybe_encode(jnp.zeros_like(p, jnp.float32),
                                        int8_state), params)
        return AdamWState(jnp.zeros((), jnp.int32), fresh(), fresh())

    def update(grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm:
            gn = global_norm(grads)
            factor = jnp.minimum(1.0, clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * factor, grads)
        else:
            gn = global_norm(grads)
        step = state.step + 1
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        is_q8 = lambda x: isinstance(x, Q8Tensor)

        def upd(g, m_enc, v_enc, p):
            m = _maybe_decode(m_enc, g, int8_state)
            v = _maybe_decode(v_enc, g, int8_state)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat, vhat = m / bc1, v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and p.ndim >= 2:      # decay matrices only
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), _maybe_encode(m, int8_state), \
                _maybe_encode(v, int8_state)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.m) if not int8_state else \
            [x for x in jax.tree.leaves(state.m, is_leaf=is_q8)]
        flat_v = tdef.flatten_up_to(state.v) if not int8_state else \
            [x for x in jax.tree.leaves(state.v, is_leaf=is_q8)]
        flat_p = jax.tree.leaves(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return updates, AdamWState(step, new_m, new_v), {"grad_norm": gn, "lr": lr_t}

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
