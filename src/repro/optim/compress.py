"""int8-compressed gradient all-reduce — the paper's quantization idea applied
to the data-parallel collective.

Inside `shard_map` over the data axes, each gradient tensor is quantized to
int8 with a per-tensor absmax scale (stochastic rounding so the compression
is unbiased), all-reduced in int32 (sums of ±127 codes fit easily), and
dequantized with the all-reduced scale-sum. Wire bytes drop 4x vs fp32 / 2x
vs bf16 — a direct lever on the collective roofline term (§Perf).

`compressed_psum(tree, axes, rng)` is a drop-in for `jax.lax.psum(tree, axes)`
(mean semantics: divide by group size at the caller like a normal grad mean).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

try:                                   # jax >= 0.6: promoted to jax.shard_map
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """Version-tolerant `shard_map`: newer jax renamed `check_rep` to
    `check_vma` and moved the function out of `jax.experimental`. Every
    caller in this repo (train step, tests) routes through here."""
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check_vma is not None:
        try:
            return _shard_map(f, **kwargs, check_vma=check_vma)
        except TypeError:
            return _shard_map(f, **kwargs, check_rep=check_vma)
    return _shard_map(f, **kwargs)


def _stochastic_round(x: jnp.ndarray, rng: jax.Array) -> jnp.ndarray:
    floor = jnp.floor(x)
    frac = x - floor
    return floor + (jax.random.uniform(rng, x.shape) < frac).astype(x.dtype)


def quantize_grad(g: jnp.ndarray, rng: jax.Array) -> tuple[jnp.ndarray, jnp.ndarray]:
    """fp grad -> (int8 codes, fp32 scale); unbiased via stochastic rounding."""
    g = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    codes = jnp.clip(_stochastic_round(g / scale, rng), -127, 127).astype(jnp.int8)
    return codes, scale


def compressed_psum(tree, axis_names, rng: jax.Array):
    """All-reduce a gradient pytree in int8-compressed form.

    Must be called inside shard_map with `axis_names` bound. Each participant
    quantizes with its own scale; codes are summed per-participant-scale
    groups: we all-gather nothing — instead we sum (codes * scale) exactly by
    reducing codes in int32 against the *max* scale across the group:
        s* = pmax(scale); codes' = round(codes * scale / s*)
        sum = psum(codes') * s*
    Requantization to the common scale loses <1 LSB per participant and stays
    unbiased in expectation via stochastic rounding.
    """
    leaves, tdef = jax.tree.flatten(tree)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for g, r in zip(leaves, rngs):
        r1, r2 = jax.random.split(r)
        codes, scale = quantize_grad(g, r1)
        smax = jax.lax.pmax(scale, axis_names)
        rescaled = codes.astype(jnp.float32) * (scale / smax)
        codes2 = jnp.clip(_stochastic_round(rescaled, r2), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(codes2, axis_names)
        out.append((total.astype(jnp.float32) * smax).astype(g.dtype))
    return tdef.unflatten(out)
