"""Optimizers + distributed-optimization tricks (int8 moments, int8-compressed
gradient all-reduce)."""
from . import adamw, compress  # noqa: F401
from .adamw import adamw as make_adamw, apply_updates, cosine_schedule, global_norm  # noqa: F401
