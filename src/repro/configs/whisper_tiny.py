"""whisper-tiny [audio] — encoder-decoder; conv frontend is a STUB
(input_specs provides precomputed 1500-frame embeddings). [arXiv:2212.04356]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51_865,
    act_fn="gelu", gated_ffn=False, norm="layernorm",
    frontend="audio", encoder_layers=4, frontend_len=1500,
    policy="w-ternary",
)
