"""Config registry: one module per assigned architecture (`--arch <id>`)."""
from __future__ import annotations

import importlib

from .base import ArchConfig, ShapeConfig, SHAPES  # noqa: F401

ARCHS = (
    "nemotron-4-340b",
    "qwen1.5-32b",
    "llama3.2-3b",
    "gemma3-4b",
    "phi-3-vision-4.2b",
    "phi3.5-moe-42b-a6.6b",
    "deepseek-moe-16b",
    "whisper-tiny",
    "xlstm-125m",
    "recurrentgemma-9b",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(name: str) -> ArchConfig:
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; have {list(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCHS}
