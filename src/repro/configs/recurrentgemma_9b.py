"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 (pattern
rglru,rglru,attn), GQA kv=1, window 2048. [arXiv:2402.19427; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048, d_rnn=4096,
    act_fn="gelu", gated_ffn=True,
    policy="w-ternary", param_dtype="bfloat16", microbatches=4,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
