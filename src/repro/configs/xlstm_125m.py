"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks, d_ff=0 (the mLSTM
up-projection plays the FFN role). [arXiv:2405.04517; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50_304,
    block_pattern=("slstm", "mlstm"),
    policy="w-ternary",
    mlstm_impl="chunkwise",   # §Perf D: validated == sequential oracle; 93x
                              # lower memory term on train_4k (scan baseline
                              # via --set mlstm_impl=scan)
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
