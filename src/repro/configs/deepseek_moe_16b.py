"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed experts,
top-6, expert d_ff=1408. [arXiv:2401.06066; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102_400,
    n_experts=64, n_shared_experts=2, top_k=6, capacity_factor=1.25,
    act_fn="silu", gated_ffn=True,
    policy="w-ternary", microbatches=8, param_dtype="bfloat16",
)
