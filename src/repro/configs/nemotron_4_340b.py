"""nemotron-4-340b [dense] — GQA kv=8, squared-ReLU (non-gated) FFN.
[arXiv:2402.16819; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256_000,
    act_fn="squared_relu", gated_ffn=False,
    policy="w-ternary",
    param_dtype="bfloat16", microbatches=16, opt_state_int8=True,
)
