"""ArchConfig — the selectable architecture/config system (`--arch <id>`).

Every assigned architecture is one `ArchConfig` in its own module under
`repro.configs`; `repro.configs.get_config(name)` resolves it, and
`.reduced()` produces the small same-family variant used by the CPU smoke
tests. Input shapes (train_4k / prefill_32k / decode_32k / long_500k) are
defined here once and attached per-arch via `supported_shapes`.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "local", "slstm", "mlstm", "rglru"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 => d_model // n_heads

    # block structure: repeating pattern of mixer kinds; () => all "attn"
    block_pattern: tuple[BlockKind, ...] = ()
    window: int = 0                   # sliding-window size for "local" mixers
    d_rnn: int = 0                    # RG-LRU width (0 => d_model)

    # transformer details
    qkv_bias: bool = False
    act_fn: str = "silu"              # silu | gelu | squared_relu
    gated_ffn: bool = True
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.0

    # modality frontend (stub per assignment: input_specs provides embeddings)
    frontend: Literal["none", "audio", "vision"] = "none"
    encoder_layers: int = 0           # >0 => encoder-decoder (whisper)
    frontend_len: int = 0             # frames/patches provided by the stub

    # quantization (the paper's technique; policy name from core.precision)
    policy: str = "w-ternary"
    kernel_backend: str = "jnp"       # "pallas" on real TPU

    # distribution / memory knobs
    seq_prefill: bool = False         # force sequential recurrent prefill
                                      # (the pre-optimization §Perf baseline)
    mlstm_impl: str = "scan"          # "scan" | "chunkwise" (§Perf B/xlstm)
    kv_cache_dtype: str = "bfloat16"  # "int8" = requantized cache (§Perf C)
    fsdp_wire: str = "dense"          # "packed" = bit-plane FSDP gathers (§Perf B)
    param_dtype: str = "float32"      # master/param dtype for training
    remat: bool = True
    scan_layers: bool = True
    microbatches: int = 1             # gradient-accumulation chunks per step
    opt_state_int8: bool = False      # int8-quantized Adam moments

    # which input shapes this arch supports (skips recorded in DESIGN.md)
    supported_shapes: tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.d_rnn == 0:
            object.__setattr__(self, "d_rnn", self.d_model)
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", ("attn",))

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def pattern_at(self, layer_idx: int) -> BlockKind:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks + head), for 6·N·D."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        dh, h, hk = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * (h + 2 * hk) * dh + h * dh * d
        ffn_mult = 3 if self.gated_ffn else 2
        dense_ffn = ffn_mult * d * f
        total = 0
        for i in range(self.n_layers):
            kind = self.pattern_at(i)
            if kind in ("attn", "local"):
                total += attn
            elif kind == "rglru":
                total += 2 * d * self.d_rnn + self.d_rnn * d + 4 * self.d_rnn + 2 * self.d_rnn
            elif kind == "mlstm":
                total += d * (h + 2 * hk) * dh + h * dh * d + 2 * h * dh * 2  # qkv+o+gates
            elif kind == "slstm":
                total += 4 * d * d + d * d  # 4 gates + out
            if self.n_experts and kind in ("attn", "local"):
                total += self.n_experts * ffn_mult * d * f + d * self.n_experts
                if self.n_shared_experts:
                    total += ffn_mult * d * (f * self.n_shared_experts)
            elif f > 0 and kind in ("attn", "local", "rglru"):
                total += dense_ffn
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.is_encdec:
            total += self.encoder_layers * (attn + dense_ffn)
            total += self.n_layers * attn  # cross-attention
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        ffn_mult = 3 if self.gated_ffn else 2
        inactive = (self.n_experts - self.top_k) * ffn_mult * d * f * self.n_layers
        return self.n_params() - inactive

    def reduced(self) -> "ArchConfig":
        """Small same-family variant for CPU smoke tests (one fwd/train step)."""
        return dataclasses.replace(
            self,
            n_layers=max(2 * len(self.block_pattern), 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            d_rnn=128,
            window=min(self.window, 64) if self.window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=8.0 if self.n_experts else 1.0,  # no drops in smoke
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_len=16 if self.frontend != "none" else 0,
            microbatches=1,
            param_dtype="float32",
        )
