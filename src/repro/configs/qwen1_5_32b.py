"""qwen1.5-32b [dense] — MHA (kv=40) with QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab=152_064,
    qkv_bias=True, act_fn="silu", gated_ffn=True,
    policy="w-ternary",
    param_dtype="bfloat16", microbatches=4,
)
