"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262_144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    act_fn="gelu", gated_ffn=True, rope_theta=1_000_000.0,
    policy="w-ternary", microbatches=2, param_dtype="bfloat16",
)
