"""Flash attention (online-softmax) Pallas TPU kernel — the 32k-prefill
compute hot-spot.

Not a BrainTTA contribution per se, but the prefill cells of every assigned
architecture are attention-bound at 32k context, and the paper's principle
applies verbatim: the wide accumulator (running max m, denominator l, output
acc) lives in VMEM scratch across the KV sweep and only the normalized bf16
tile is written back — "requantize as early as possible" for softmax.

Layout: q (BH, Tq, dh), k/v (BHk, Tk, dh) — GQA is expressed in the index
map (query head bh reads kv head bh // group). Grid (BH, nq, nk), nk
innermost (output-stationary in the q tile). Causal masking by absolute
block positions; fully-masked kv blocks still iterate (masked) — the
triangular-schedule skip is a known further optimization (EXPERIMENTS.md).

Validated in interpret mode against ref.flash_attention_ref over a
shape/GQA/causal sweep (tests/test_flash_attn.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, bq, bk):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, dh)
    k = k_ref[0]                                   # (bk, dh)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 256, bk: int = 256,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Tq, dh); k, v: (BHk, Tk, dh); BH % BHk == 0 (GQA groups).

    Returns (BH, Tq, dh) in q's dtype. Block sizes clamp to the problem and
    must divide it (ops-level callers pad).
    """
    bh, tq, dh = q.shape
    bhk, tk, _ = k.shape
    assert bh % bhk == 0
    g = bh // bhk
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0, (tq, tk, bq, bk)
    grid = (bh, tq // bq, tk // bk)
    scale = 1.0 / dh ** 0.5

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (h // g, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
