"""int8 MXU MAC body — the 8-bit vMAC path.

BrainTTA's 8-bit mode (v_C=4 operands/word) maps directly onto the TPU MXU's
native int8×int8→int32 path — this is where the ASIC→TPU translation is an
upgrade, not an emulation. The BrainTTA-specific part (the requantization
epilogue fused immediately behind the MAC, §IV-B) lives once in
`harness.gemm`; this module is just the dot body. Weight codes use the
K-major (K, N) layout XLA's int8 dot prefers, hence w_kmajor=True.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .harness import MacBody, Tile, gemm


def _i8_step(xs, ws, accs, *, bkq):
    dot = jax.lax.dot_general(xs[0], ws[0], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (accs[0] + dot,)


I8_DOT = MacBody("i8gemm", n_x=1, n_w=1, n_acc=1, k_per_q=1,
                 step=_i8_step, finish=lambda accs, k: accs[0],
                 w_kmajor=True, default_bkq=512)


def i8gemm(x_q: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
           a_scale: jnp.ndarray, bias: jnp.ndarray | None = None, *,
           bm: int = 128, bn: int = 128, bk: int = 512,
           interpret: bool = True) -> jnp.ndarray:
    """(M, K)i8 × (K, N)i8 → (M, N) bf16 with fused requant epilogue."""
    return gemm(I8_DOT, (x_q,), (w_q,), w_scale, a_scale, bias,
                k=x_q.shape[1], tile=Tile(bm, bn, bk), interpret=interpret)
