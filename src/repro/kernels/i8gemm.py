"""int8 GEMM with fused requantization epilogue — the 8-bit vMAC path.

BrainTTA's 8-bit mode (v_C=4 operands/word) maps directly onto the TPU MXU's
native int8×int8→int32 path — this is where the ASIC→TPU translation is an
upgrade, not an emulation. The BrainTTA-specific part is the *epilogue*:
requantization fused immediately behind the MAC (§IV-B), so the int32
accumulator is rescaled (per-output-channel w_scale × per-row a_scale,
+ bias) inside VMEM and only the narrow result is written back to HBM.

Output-stationary K-sweep like bgemm/tgemm; MXU-aligned blocks
(multiples of (8,128); defaults 128×128×512).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _i8gemm_kernel(x_ref, w_ref, ws_ref, as_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * ws_ref[...][None, :] * as_ref[...][:, None]
        y = y + b_ref[...][None, :]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def i8gemm(x_q: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
           a_scale: jnp.ndarray, bias: jnp.ndarray | None = None, *,
           bm: int = 128, bn: int = 128, bk: int = 512,
           interpret: bool = True) -> jnp.ndarray:
    """(M, K)i8 × (K, N)i8 → (M, N) bf16 with fused requant epilogue."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    if bias is None:
        bias = jnp.zeros((n,), jnp.float32)

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _i8gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, w_scale, a_scale, bias)
