"""Binary XNOR+popcount MAC bodies — the vBMAC unit (§III).

Operands arrive bit-packed along K (32 MACs per uint32 word, v_C=32). Two
formulations of the same contract, both riding `harness.gemm`'s shared
output-stationary skeleton:

  BINARY_POPCOUNT — paper-faithful VPU path: XOR + population_count + add is
                    the direct analogue of the XNOR+popcount reduction tree;
                    the dot is recovered as K − 2·mismatches in finish().
  BINARY_MXU      — beyond-paper: unpack both packed tiles to ±1 *in VMEM*
                    and ride the MXU (dense-rate compute, packed HBM traffic).

The grid/BlockSpec/accumulator/requant-epilogue scaffold lives in
`repro.kernels.harness`; registration into the serve stack lives in
`repro.kernels.dispatch`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pack

from .harness import MacBody, Tile, gemm

WORD = 32


def _popcount_step(xs, ws, accs, *, bkq):
    x, w = xs[0], ws[0]                     # (bm, bkq), (bn, bkq) uint32

    def body(i, acc):
        xi = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=1)        # (bm, 1)
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=1)        # (bn, 1)
        mism = jax.lax.population_count(jnp.bitwise_xor(xi, wi.T))
        return acc + mism.astype(jnp.int32)

    return (jax.lax.fori_loop(0, bkq, body, accs[0]),)


def _popcount_finish(accs, k_total):
    return jnp.int32(k_total) - 2 * accs[0]        # dot = K - 2*mismatches


BINARY_POPCOUNT = MacBody("bgemm_popcount", n_x=1, n_w=1, n_acc=1,
                          k_per_q=WORD, step=_popcount_step,
                          finish=_popcount_finish)


def _mxu_step(xs, ws, accs, *, bkq):
    k = bkq * WORD
    xf = pack.unpack_pm1_i8(xs[0], k).astype(jnp.float32)   # (bm, 32*bkq)
    wf = pack.unpack_pm1_i8(ws[0], k).astype(jnp.float32)   # (bn, 32*bkq)
    dot = jax.lax.dot_general(xf, wf, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return (accs[0] + dot.astype(jnp.int32),)


BINARY_MXU = MacBody("bgemm_mxu", n_x=1, n_w=1, n_acc=1, k_per_q=WORD,
                     step=_mxu_step, finish=lambda accs, k: accs[0],
                     unpacks_f32=True)


def bgemm(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
          w_scale: jnp.ndarray, a_scale: jnp.ndarray, *, k: int,
          bm: int = 128, bn: int = 128, bkw: int = 16,
          impl: str = "popcount", interpret: bool = True) -> jnp.ndarray:
    """Packed binary GEMM: (M, K/32)u32 × (N, K/32)u32 → (M, N) bf16."""
    body = BINARY_POPCOUNT if impl == "popcount" else BINARY_MXU
    return gemm(body, (x_packed,), (w_packed,), w_scale, a_scale,
                k=k, tile=Tile(bm, bn, bkw), interpret=interpret)
