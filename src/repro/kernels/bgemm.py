"""Binary XNOR+popcount GEMM — the vBMAC unit as a Pallas TPU kernel.

BrainTTA's binary datapath (§III): 1024-bit vectors, 32 reduction trees of 32
binary inputs each, output-stationary accumulation, requantization fused
behind the MAC (§IV-B "as early as possible"). TPU mapping (DESIGN.md §6):

  * operands arrive bit-packed along K: 32 MACs per uint32 word (v_C=32),
  * the grid is (M/bm, N/bn, K/bkw) with K innermost → the int32 accumulator
    tile lives in VMEM scratch across the K sweep (output-stationary),
  * per K-word compute is XOR + population_count + add on the VPU — the
    direct analogue of the XNOR+popcount reduction tree,
  * the epilogue (last K step) applies the fused requant
    (dot = K − 2·mismatches) · w_scale[n] · a_scale[m] and writes bf16 —
    the wide accumulator never leaves VMEM.

Two kernels are provided:
  bgemm_popcount — paper-faithful VPU formulation above.
  bgemm_mxu      — beyond-paper: unpack the weight tile to ±1 inside VMEM and
                   ride the MXU (dense-rate compute, packed HBM traffic). Same
                   contract, used by the §Perf hillclimb.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD = 32


def _bgemm_popcount_kernel(x_ref, w_ref, ws_ref, as_ref, o_ref, acc_ref, *, k_total, bkw):
    """One (bm, bn) output tile; grid dim 2 sweeps K words (output-stationary)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, bkw) uint32
    w = w_ref[...]  # (bn, bkw) uint32

    def body(i, acc):
        xi = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=1)      # (bm, 1)
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=1)      # (bn, 1)
        mism = jax.lax.population_count(jnp.bitwise_xor(xi, wi.T))  # (bm, bn)
        return acc + mism.astype(jnp.int32)

    acc_ref[...] = jax.lax.fori_loop(0, bkw, body, acc_ref[...])

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        dot = jnp.int32(k_total) - 2 * acc_ref[...]
        y = dot.astype(jnp.float32) * ws_ref[...][None, :] * as_ref[...][:, None]
        o_ref[...] = y.astype(o_ref.dtype)


def _bgemm_mxu_kernel(x_ref, w_ref, ws_ref, as_ref, o_ref, acc_ref, *, k_total, bkw):
    """MXU variant: unpack both tiles to ±1 in VMEM, dense int-dot."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    shifts = jnp.arange(WORD, dtype=jnp.uint32)

    def unpack_pm1(words):  # (R, bkw) -> (R, bkw*32) float32 in {-1,+1}
        bits = (words[..., None] >> shifts) & jnp.uint32(1)
        bits = bits.reshape(words.shape[0], -1)
        return bits.astype(jnp.float32) * 2.0 - 1.0

    xf = unpack_pm1(x_ref[...])          # (bm, 32*bkw)
    wf = unpack_pm1(w_ref[...])          # (bn, 32*bkw)
    acc_ref[...] += jax.lax.dot_general(  # MXU: contract K
        xf, wf, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * ws_ref[...][None, :] * as_ref[...][:, None]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "bkw", "impl", "interpret"))
def bgemm(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
          w_scale: jnp.ndarray, a_scale: jnp.ndarray, *, k: int,
          bm: int = 128, bn: int = 128, bkw: int = 16,
          impl: str = "popcount", interpret: bool = True) -> jnp.ndarray:
    """Packed binary GEMM: (M, K/32)u32 × (N, K/32)u32 → (M, N) bf16.

    Block sizes are clamped to the problem and must divide it; `ops.py`
    handles padding/selection. `interpret=True` on CPU (validation), False on
    real TPU.
    """
    m, kw = x_packed.shape
    n, kw2 = w_packed.shape
    assert kw == kw2 and kw * WORD == k, (x_packed.shape, w_packed.shape, k)
    bm, bn, bkw = min(bm, m), min(bn, n), min(bkw, kw)
    assert m % bm == 0 and n % bn == 0 and kw % bkw == 0, (m, n, kw, bm, bn, bkw)

    kern = _bgemm_popcount_kernel if impl == "popcount" else _bgemm_mxu_kernel
    grid = (m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        functools.partial(kern, k_total=k, bkw=bkw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bkw), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_packed, w_packed, w_scale, a_scale)
