"""Precision-keyed GEMM dispatch — the single entry point of the serve stack.

BrainTTA serves binary, ternary and int8 operands through one flexible
datapath (§III); this module is that datapath's software twin. Every serve
GEMM in the repo — `core.qlinear.apply(mode="serve")`, the Pallas backend
that used to live in `kernels.ops`, the launch drivers and the benches —
funnels through

    qgemm(p, x, spec, op)

where `op` is an `OperatingPoint`: the frozen, structured description of one
datapath configuration — weight precision, activation precision, kernel
formulation (`impl`), execution backend, and an optional `Tile` block-shape
override. `qgemm` owns, exactly once, everything the four call sites used to
copy: activation quantization/packing, M-padding, block-size selection
(explicit `Tile` or the per-cell `TuneTable`), expert vmap, and the
bias/requant epilogue (fused in-kernel on the Pallas backend, single f32
requant on the jnp backend — no separate bias round-trip).

The registry maps operating points to `GemmCell`s, keyed by
(wprec, aprec, impl) — backend and tile are execution choices, not cells:
every cell serves both backends. Each cell holds the ONE implementation of
its formulation:

  prep  — activation quantize/pack (shared verbatim by both backends, so
          jnp-vs-pallas equivalence is an algebra check, not a tolerance
          dance)
  acc   — the jnp accumulator formulation (XLA backend / CPU dry-run)
  body  — the Pallas `MacBody` riding `harness.gemm`'s shared skeleton
          (None = no packed kernel; the jnp formulation serves both
          backends, e.g. the weight-only cells whose activations stay bf16
          on the MXU — quantizing them here would silently change the
          algebra vs QAT)

Weight and activation precisions may DIFFER per cell (mixed w/a datapath,
§II-A "some layers are more resilient to quantization than others"): the
w-ternary × a-int8 cell contracts trit weight planes against int8 activation
codes, and the int4 cells unpack s4 nibble words — the requant epilogue
composes the per-channel weight scale with the per-row activation scale
regardless of how the two sides were quantized.

Adding a precision or kernel variant = one prep/acc/body triple + one
`register()` call. `impl="*"` marks formulation-agnostic cells (int8/int4
have no popcount/mxu split; weight-only cells ignore impl).

`python -m repro.kernels.dispatch --list` prints the live registry.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import pack
from repro.core.quantize import int8_codes, ternarize

from . import bgemm, i4gemm, i8gemm, pgemm, tgemm
from . import harness
from .harness import Tile  # re-export: the OperatingPoint tile override type

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"

#: Pallas kernels need M padded to the sublane multiple.
PAD_M = 8

_BACKENDS = ("jnp", "pallas")


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One configuration of the flexible datapath, as a first-class value.

    Replaces the loose (wprec, aprec, impl) string tuples and scattered
    `impl=`/`backend=` kwargs of the old API. wprec/aprec name the registry
    cell; impl selects the kernel formulation ("popcount" | "mxu", or "*"
    when the cell is formulation-agnostic); backend selects where the cell's
    formulation executes; tile (a `harness.Tile`) overrides the block shapes
    — when None, `qgemm` consults the per-cell `TuneTable`.
    """
    wprec: str = "none"
    aprec: str = "none"
    impl: str = "popcount"
    backend: str = "jnp"
    tile: Tile | None = None
    #: leading (MSB-first) plane count a plane-composed cell contracts; None
    #: = the full stack. An execution choice like backend/tile, NOT part of
    #: the registry key — the self-speculative draft pass runs the SAME cell
    #: over the same weights with planes=1/2 (truncated-plane approximation).
    planes: int | None = None

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend={self.backend!r}; have {_BACKENDS}")
        if self.planes is not None and self.planes < 1:
            raise ValueError(f"planes={self.planes!r}: need >= 1 or None")

    @property
    def key(self) -> tuple[str, str, str]:
        """The structured registry key (backend/tile are execution choices)."""
        return (self.wprec, self.aprec, self.impl)

    @property
    def tag(self) -> str:
        trunc = "" if self.planes is None else f":p{self.planes}"
        return (f"w{self.wprec[:4]}/a{self.aprec[:4]}/{self.impl}{trunc}"
                f"@{self.backend}")

    @classmethod
    def for_spec(cls, spec, *, impl: str = "popcount", backend: str = "jnp",
                 tile: Tile | None = None) -> "OperatingPoint":
        """The per-layer operating point: precisions from the layer's
        `LayerQuant` (set by the `PrecisionPolicy`), formulation/backend from
        the execution context. This is how the serve path resolves a
        heterogeneous policy layer by layer instead of from one global flag
        pair."""
        return cls(spec.lq.weights.precision, spec.lq.acts.precision,
                   impl=impl, backend=backend, tile=tile)


@dataclasses.dataclass(frozen=True)
class GemmCell:
    """One registered operating point of the datapath.

    `op` is the structured registry key (its backend/tile fields are ignored
    at registration — a cell serves both backends; tiles come from the
    caller's OperatingPoint or the TuneTable)."""
    op: OperatingPoint
    weight_names: tuple[str, ...]   # packed-param entries feeding the GEMM
    prep: Callable                  # (x2d, p, spec) -> (x_ops, a_scale|None)
    acc: Callable                   # (x_ops, w_ops, k) -> (M, N) accumulator
    body: harness.MacBody | None = None   # Pallas tile body (None = jnp only)
    wide: bool = True               # f32 requant (W&A) vs bf16 (weight-only)

    @property
    def wprec(self) -> str:
        return self.op.wprec

    @property
    def aprec(self) -> str:
        return self.op.aprec

    @property
    def impl(self) -> str:
        return self.op.impl

    @property
    def key(self) -> tuple[str, str, str]:
        return self.op.key

    @property
    def tag(self) -> str:
        return f"w{self.wprec[:3]}/a{self.aprec[:3]}/{self.impl}"

    @property
    def k_quantum(self) -> int:
        """K elements per storage unit of the cell's packed weight axis —
        the pack factor tensor-parallel K-sharding must respect (32 for the
        bit-plane formats, 8 for s4 nibbles, 1 for int8/dense)."""
        return max((pack.K_QUANTUM.get(nm, 1) for nm in self.weight_names),
                   default=1)


_REGISTRY: dict[tuple[str, str, str], GemmCell] = {}


def register(cell: GemmCell) -> GemmCell:
    if cell.key in _REGISTRY:
        raise ValueError(f"duplicate GEMM registration for {cell.key}")
    _REGISTRY[cell.key] = cell
    return cell


def _nearest_key(key: tuple[str, str, str]) -> tuple[str, str, str] | None:
    """Closest registered cell to an unknown key, wildcard-aware: rank by
    matching wprec, then aprec, then impl (a '*' cell matches any impl)."""
    def score(have: tuple[str, str, str]) -> tuple[int, int, int]:
        return (int(have[0] == key[0]), int(have[1] == key[1]),
                int(have[2] in (key[2], "*")))
    return max(sorted(_REGISTRY), key=score, default=None)


def lookup(op, aprec: str | None = None, impl: str = "popcount") -> GemmCell:
    """Resolve an operating point to its cell; impl falls back to '*'.

    Primary form: lookup(OperatingPoint(...)). The legacy
    lookup(wprec, aprec, impl) string form resolves identically.
    """
    key = op.key if isinstance(op, OperatingPoint) else (op, aprec, impl)
    for k in (key, (key[0], key[1], "*")):
        if k in _REGISTRY:
            return _REGISTRY[k]
    near = _nearest_key(key)
    hint = ""
    if near is not None:
        hint = (f"; nearest registered cell is (wprec={near[0]!r}, "
                f"aprec={near[1]!r}, impl={near[2]!r})")
    raise KeyError(
        f"no GEMM registered for (wprec={key[0]!r}, aprec={key[1]!r}, "
        f"impl={key[2]!r}){hint} — run `python -m repro.kernels.dispatch "
        f"--list` for the full registry")


def cells() -> dict[tuple[str, str, str], GemmCell]:
    """Snapshot of the registry (tests / benches iterate this)."""
    return dict(_REGISTRY)


def operating_points(backend: str = "jnp") -> list[OperatingPoint]:
    """Every registered cell as a concrete OperatingPoint on `backend`."""
    return [dataclasses.replace(c.op, backend=backend)
            for _, c in sorted(_REGISTRY.items())]


# ---------------------------------------------------------------------------
# TuneTable — per-cell Tile choices as data, not code
# ---------------------------------------------------------------------------

DEFAULT_TUNE_PATH = os.path.join(os.path.dirname(__file__), "tune_cpu.json")


@dataclasses.dataclass(frozen=True)
class TuneTable:
    """Per-cell `Tile` map consulted when an OperatingPoint carries no
    explicit tile — the ROADMAP's "autotune per operating point" as a JSON
    data file. Keys are registry keys; an impl of "*" matches any
    formulation of that (wprec, aprec) pair (same fallback as `lookup`).

    The in-repo default (`tune_cpu.json`, regenerated by
    `python -m benchmarks.kernel_bench --retune`) is measured in
    interpret mode on CPU — a correctness-scale baseline; a real-TPU sweep
    drops in as another JSON file via `load()` / `launch.serve --tune`.
    """
    tiles: Mapping[tuple[str, str, str], Tile]
    source: str = ""

    def tile_for(self, op: OperatingPoint) -> Tile | None:
        for key in (op.key, (op.wprec, op.aprec, "*")):
            if key in self.tiles:
                return self.tiles[key]
        return None

    @classmethod
    def load(cls, path: str) -> "TuneTable":
        with open(path) as f:
            raw = json.load(f)
        tiles = {}
        for name, t in raw.get("cells", {}).items():
            wprec, aprec, impl = name.split("/")
            tiles[(wprec, aprec, impl)] = Tile(
                bm=int(t["bm"]), bn=int(t["bn"]),
                bkq=None if t.get("bkq") is None else int(t["bkq"]))
        return cls(tiles=tiles, source=str(raw.get("source", path)))

    def save(self, path: str) -> None:
        cells_json = {
            "/".join(key): {"bm": t.bm, "bn": t.bn, "bkq": t.bkq}
            for key, t in sorted(self.tiles.items())}
        with open(path, "w") as f:
            json.dump({"source": self.source, "cells": cells_json}, f,
                      indent=2, sort_keys=True)
            f.write("\n")


def valid_tune_keys(extra_keys=()) -> set:
    """Every tune-table key the CURRENT registry can resolve: exact cell
    keys, the `(wprec, aprec, "*")` wildcard of each registered pair (the
    `tile_for` fallback), plus pseudo-cell keys owned by non-qgemm kernels
    (the paged-attn decode walk passes its own via `extra_keys`)."""
    keys = set(_REGISTRY)
    keys |= {(w, a, "*") for (w, a, _i) in _REGISTRY}
    keys |= set(extra_keys)
    return keys


def prune_stale_tiles(tiles: Mapping, extra_keys=()
                      ) -> tuple[dict, list]:
    """Split a tune-table tile map into (kept, dropped_keys): rows whose op
    key no longer matches any registered cell (a renamed impl, a retired
    precision pair) are dead data — `tile_for` can never reach them — and
    `kernel_bench --retune` prunes them instead of carrying them forever."""
    valid = valid_tune_keys(extra_keys)
    kept = {k: t for k, t in tiles.items() if k in valid}
    dropped = sorted(k for k in tiles if k not in valid)
    return kept, dropped


@functools.lru_cache(maxsize=1)
def default_tune() -> TuneTable:
    if os.path.exists(DEFAULT_TUNE_PATH):
        return TuneTable.load(DEFAULT_TUNE_PATH)
    return TuneTable(tiles={}, source="(no tune table shipped)")


def _resolve_tile(op: OperatingPoint) -> Tile | None:
    """Explicit OperatingPoint tile, else the shipped TuneTable's choice."""
    return op.tile if op.tile is not None else default_tune().tile_for(op)


# ---------------------------------------------------------------------------
# activation prep — ONE quantize+pack per activation precision
# ---------------------------------------------------------------------------

def _prep_binary(x2d, p, spec):
    xf = x2d.astype(jnp.float32)
    a_scale = jnp.mean(jnp.abs(xf), axis=-1)          # XNOR-Net per-row alpha
    xp = pack.pack_binary(jnp.where(xf >= 0, 1.0, -1.0))
    return (xp,), a_scale


def _prep_ternary(x2d, p, spec):
    xf = x2d.astype(jnp.float32)
    a_scale = jnp.mean(jnp.abs(xf), axis=-1)
    # per-row threshold (axis=-1): under continuous batching a per-tensor
    # threshold couples co-batched requests — one slot's activations would
    # move every other slot's ternarization cut
    xq = jax.lax.stop_gradient(
        ternarize(xf, spec.lq.acts.ternary_threshold, axis=-1))
    xm, xs = pack.pack_ternary(xq)
    return (xm, xs), a_scale


def _prep_int8(x2d, p, spec):
    a_s = p["a_scale"]     # calibrated constant; KeyError = packing bug,
    xq = int8_codes(x2d.astype(jnp.float32), a_s)  # not a default to paper over
    return (xq,), jnp.full((x2d.shape[0],), a_s, jnp.float32)


def _prep_bf16(x2d, p, spec):
    """Weight-only / dense: activations stay bf16 (MXU path)."""
    return (x2d.astype(jnp.bfloat16),), None


# ---------------------------------------------------------------------------
# jnp accumulator formulations — ONE per registered cell
# ---------------------------------------------------------------------------

def _acc_binary_popcount(x_ops, w_ops, k):
    return pack.binary_dot_words(x_ops[0][:, None, :], w_ops[0], k)


def _acc_binary_mxu(x_ops, w_ops, k):
    x = pack.unpack_pm1_i8(x_ops[0], k)                # (M, K) ±1 int8
    w = pack.unpack_pm1_i8(w_ops[0], k)                # (N, K)
    return jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_ternary_popcount(x_ops, w_ops, k):
    return pack.ternary_dot_words(x_ops[0][:, None, :], x_ops[1][:, None, :],
                                  w_ops[0], w_ops[1])


def _acc_ternary_mxu(x_ops, w_ops, k):
    x = pack.unpack_ternary_i8(x_ops[0], x_ops[1], k)  # (M, K) trits int8
    w = pack.unpack_ternary_i8(w_ops[0], w_ops[1], k)  # (N, K)
    return jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_int8(x_ops, w_ops, k):
    return jax.lax.dot_general(x_ops[0], w_ops[0], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_wternary_aint8(x_ops, w_ops, k):
    """Mixed w-ternary × a-int8: int8 codes against unpacked trit planes."""
    w = pack.unpack_ternary_i8(w_ops[0], w_ops[1], k)  # (N, K) trits int8
    return jax.lax.dot_general(x_ops[0], w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_wint4_aint8(x_ops, w_ops, k):
    """int4 weights (s4 nibble words) × int8 activation codes."""
    w = pack.unpack_int4_i8(w_ops[0], k)               # (N, K) s4-as-int8
    return jax.lax.dot_general(x_ops[0], w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_planes(x_ops, w_ops, k, *, bits):
    """Plane-composed weights x int8 activation codes: the stacked binary
    planes compose back to the exact b-bit codes (or their leading-P
    truncation), then one int8 MXU dot — integer arithmetic end to end, so
    the result is bit-identical to the direct int4/int8 cells."""
    w = pack.unpack_planes_i8(w_ops[0], k, bits)       # (N, K) composed codes
    return jax.lax.dot_general(x_ops[0], w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_wonly_binary(x_ops, w_ops, k):
    w = pack.unpack_pm1_i8(w_ops[0], k)                # (N, K)
    return x_ops[0] @ w.astype(x_ops[0].dtype).T


def _acc_wonly_ternary(x_ops, w_ops, k):
    w = pack.unpack_ternary_i8(w_ops[0], w_ops[1], k)
    return x_ops[0] @ w.astype(x_ops[0].dtype).T


def _acc_wonly_int4(x_ops, w_ops, k):
    w = pack.unpack_int4_i8(w_ops[0], k)               # (N, K) s4 codes
    return x_ops[0] @ w.astype(x_ops[0].dtype).T


def _acc_wonly_int8(x_ops, w_ops, k):
    return x_ops[0] @ w_ops[0].astype(x_ops[0].dtype)  # w_q is (K, N)


def _acc_dense(x_ops, w_ops, k):
    return x_ops[0] @ w_ops[0]


# ---------------------------------------------------------------------------
# the registry — every operating point of the POLICIES table
# ---------------------------------------------------------------------------

def _op(wprec, aprec, impl):
    return OperatingPoint(wprec, aprec, impl)


# W&A-quantized cells: packed operands, int accumulators, Pallas bodies.
register(GemmCell(_op("binary", "binary", "popcount"), ("w_packed",),
                  _prep_binary, _acc_binary_popcount, body=bgemm.BINARY_POPCOUNT))
register(GemmCell(_op("binary", "binary", "mxu"), ("w_packed",),
                  _prep_binary, _acc_binary_mxu, body=bgemm.BINARY_MXU))
register(GemmCell(_op("ternary", "ternary", "popcount"), ("w_mask", "w_sign"),
                  _prep_ternary, _acc_ternary_popcount,
                  body=tgemm.TERNARY_POPCOUNT))
register(GemmCell(_op("ternary", "ternary", "mxu"), ("w_mask", "w_sign"),
                  _prep_ternary, _acc_ternary_mxu, body=tgemm.TERNARY_MXU))
register(GemmCell(_op("int8", "int8", "*"), ("w_q",),
                  _prep_int8, _acc_int8, body=i8gemm.I8_DOT))

# mixed w/a cells: the two operand sides quantize independently; the shared
# requant epilogue composes the per-channel weight scale (ternary alpha / s4
# scale) with the per-row int8 activation scale — no matched-precision
# assumption anywhere.
register(GemmCell(_op("ternary", "int8", "*"), ("w_mask", "w_sign"),
                  _prep_int8, _acc_wternary_aint8, body=tgemm.TERNARY_W_I8A))
register(GemmCell(_op("int4", "int8", "*"), ("w_q4",),
                  _prep_int8, _acc_wint4_aint8, body=i4gemm.INT4_W_I8A))

# plane-composed cells: int4/int8 weights stored as stacked binary planes
# (pack.pack_planes, MSB-first two's complement), contracted by looping the
# binary plane datapath with shifted coefficients — bit-exact vs the direct
# cells above. impl="planes" coexists with the "*" wildcard rows: exact key
# wins in lookup(), so a policy pair resolves to these only when asked.
# OperatingPoint.planes truncates the stack (the self-speculative draft).
register(GemmCell(_op("int4", "int8", "planes"), ("w_planes",),
                  _prep_int8, functools.partial(_acc_planes, bits=4),
                  body=pgemm.PLANES_W4_I8A))
register(GemmCell(_op("int8", "int8", "planes"), ("w_planes",),
                  _prep_int8, functools.partial(_acc_planes, bits=8),
                  body=pgemm.PLANES_W8_I8A))

# weight-only cells: bf16 acts end-to-end so the row-parallel TP partial-sum
# reduces in bf16 (2x wire, §Perf A); requant stays in bf16 (wide=False).
register(GemmCell(_op("binary", "none", "*"), ("w_packed",),
                  _prep_bf16, _acc_wonly_binary, wide=False))
register(GemmCell(_op("ternary", "none", "*"), ("w_mask", "w_sign"),
                  _prep_bf16, _acc_wonly_ternary, wide=False))
register(GemmCell(_op("int4", "none", "*"), ("w_q4",),
                  _prep_bf16, _acc_wonly_int4, wide=False))
register(GemmCell(_op("int8", "none", "*"), ("w_q",),
                  _prep_bf16, _acc_wonly_int8, wide=False))
register(GemmCell(_op("none", "none", "*"), ("w",),
                  _prep_bf16, _acc_dense, wide=False))


# ---------------------------------------------------------------------------
# tensor parallelism: qgemm under shard_map
# ---------------------------------------------------------------------------
#
# Megatron pairing over the ("data", "model") mesh:
#
#   column-parallel (qkv/up):  weights N-sharded over the model axis; every
#       shard sees the full K, runs the COMPLETE plain qgemm (prep + acc +
#       requant) on its N slice — no collective, bit-exact per slice.
#   row-parallel (out/down):   packed K-sharded. Activation prep (per-row
#       alpha / trit threshold / int8 codes) runs REPLICATED inside the
#       shard_map body on the full K — the per-row statistics must see every
#       K element, and computing them on the full row keeps the algebra
#       identical to the single-device path. Each shard then slices its own
#       packed-K chunk, accumulates its partial dot, and the partials are
#       psum'd on the int32 accumulator BEFORE requant: integer addition is
#       associative, so the TP sum is bit-exact; requantizing per-shard
#       partials would be numerically wrong (and f32/bf16 psum inexact).
#
# Weight-only cells (wide=False) keep bf16 accumulators — a narrow psum is
# NOT bit-exact, so row-parallel falls back to replicated compute for them
# (column-parallel still shards: it needs no collective). The batch/M dim
# additionally shards over the "data" axis when it divides.

def _shard_map(f, *, mesh, in_specs, out_specs):
    """The repo's one version-tolerant shard_map (optim.compress owns it),
    with replication checking off: Pallas calls inside the body have no
    replication rule on older jax."""
    from repro.optim.compress import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


@dataclasses.dataclass(frozen=True)
class TPSpec:
    """Tensor-parallel context threaded from the serve driver into qgemm."""
    mesh: Any                       # jax.sharding.Mesh
    axis: str = "model"             # TP (contraction/out-dim) axis name

    @property
    def size(self) -> int:
        return int(self.mesh.shape[self.axis])


#: per-leaf axis positions (negative = from the end; leading expert axis ok)
_N_AXIS = {"w_packed": -2, "w_mask": -2, "w_sign": -2, "w_q4": -2,
           "w_planes": -2, "w_q": -1, "w": -1, "w_scale": -1, "b": -1}
_K_AXIS = {"w_packed": -1, "w_mask": -1, "w_sign": -1, "w_q4": -1,
           "w_planes": -1, "w_q": -2, "w": -2}


# ---------------------------------------------------------------------------
# expert parallelism: grouped qgemm under shard_map
# ---------------------------------------------------------------------------
#
# MoE expert stacks carry a leading E axis that launch/sharding.py already
# places over the "model" mesh axis. EPSpec makes the COMPUTE exploit that
# placement: instead of every shard running all E experts (the dense expert
# vmap, replicated work), each shard runs only its E/ns local experts on
# their capacity-dispatched token slabs — the grouped expert dispatch.
#
#   up (parallel="column"):  each shard runs the COMPLETE per-expert qgemm
#       (prep + acc + requant) on its local expert stack; the output stays
#       expert-sharded over the model axis and the elementwise activation
#       between up and down needs no collective.
#   down (parallel="row"):   each shard's local int accumulators are zero-
#       embedded into the full (E, M, N) at offset shard*e_loc and psum'd —
#       ONE collective, before requant, mirroring row-parallel TP's recipe.
#       Unlike TP-row's true K-reduction, every (e, m, n) element here is
#       produced by exactly ONE shard (zeros elsewhere), so the psum is a
#       disjoint ASSEMBLY — exact at any accumulator width (x + 0 == x in
#       IEEE) — and narrow weight-only cells (bf16 accumulators, e.g. the
#       w-ternary deepseek policy) are EP-shardable where TP-row must fall
#       back to replicated compute. The replicated assembled output then
#       feeds the combine einsum exactly as the single-device oracle does.
#
# Routing (models/moe.py) stays replicated: the router is tiny, and running
# it identically everywhere keeps top-k/capacity drops deterministic and
# bit-identical to the dense-vmap oracle.

@dataclasses.dataclass(frozen=True)
class EPSpec:
    """Expert-parallel context threaded from the serve driver into qgemm.

    Lives beside TPSpec: tp shards WITHIN a (possibly expert-stacked) layer
    (N or packed-K over the model axis), ep shards the expert stack ITSELF
    (leading E axis over the same axis). `ep_plan` arbitrates; when EP does
    not apply the call falls through to the tp/vmap paths unchanged."""
    mesh: Any                       # jax.sharding.Mesh
    axis: str = "model"             # expert axis name on the mesh

    @property
    def size(self) -> int:
        return int(self.mesh.shape[self.axis])


def ep_shardable(n_experts: int, n_shards: int) -> bool:
    """Whole-expert placement predicate: each shard must own an integral
    number of expert stacks. Shared with launch/sharding's `fit_spec` drop
    (E % shards != 0 replicates the leading axis there, and falls back to
    the dense vmap here), so device layout and compute always agree."""
    return n_experts > 0 and n_shards > 0 and n_experts % n_shards == 0


def ep_plan(cell: GemmCell, spec, parallel: str, ep: "EPSpec | None"
            ) -> str | None:
    """Resolve the effective EP mode, or None => dense-vmap/TP fallback.

    Guards: an expert stack, a live mesh axis with size > 1, whole experts
    per shard (`ep_shardable`, the same predicate behind the sharding rules'
    fit_spec drop), and a K axis that is a whole number of packed storage
    units (`cell.k_quantum`, reusing pack.K_QUANTUM). E-axis sharding never
    splits a packed word by construction — each expert's (N, K/q) planes
    move whole — so the k-quantum check is a layout-integrity invariant,
    not a divisibility-by-shards constraint like TP-row's."""
    if ep is None or not spec.experts or parallel not in ("column", "row"):
        return None
    if ep.axis not in ep.mesh.axis_names:
        return None
    ns = ep.size
    if ns <= 1 or not ep_shardable(spec.experts, ns):
        return None
    if spec.in_dim % cell.k_quantum:
        return None
    return parallel


def _ep_pspec(ep: EPSpec, nm: str, v) -> P:
    """Expert-stacked leaves shard their LEADING E axis; scalars and shared
    leaves (a_scale) replicate."""
    if v.ndim == 0 or nm == "a_scale":
        return P(*([None] * v.ndim))
    return P(ep.axis, *([None] * (v.ndim - 1)))


def _ep_column(cell, p, x, spec, op, ep):
    """Expert-sharded up projection: each shard runs the plain per-expert
    qgemm (the dense-vmap path) on its local E/ns expert stack and token
    slabs. No collective — the output stays expert-sharded over the model
    axis, which is exactly the layout the elementwise activation and the
    row-parallel down projection consume."""
    mesh, ax, ns = ep.mesh, ep.axis, ep.size
    e, k, n = spec.experts, spec.in_dim, spec.out_dim
    lead = x.shape[:-1]
    x3 = x.reshape(e, -1, k)
    sub = dataclasses.replace(spec, experts=e // ns)
    pspecs = {nm: _ep_pspec(ep, nm, v) for nm, v in p.items()}
    fn = lambda pl_, xl: qgemm(pl_, xl, sub, op)
    y = _shard_map(fn, mesh=mesh, in_specs=(pspecs, P(ax, None, None)),
                   out_specs=P(ax, None, None))(p, x3)
    return y.reshape(*lead, n)


def _ep_row(cell, p, x, spec, op, ep):
    """Expert-sharded down projection: per-local-expert accumulators, zero-
    embedded into the full (E, M, N) at this shard's expert offset, ONE psum
    over the model axis (the scatter-back), deferred global requant.

    Exactness: the psum sums one real accumulator block with ns-1 zero
    blocks per element — a disjoint assembly, exact at any width — so both
    wide (int32) and narrow (bf16, weight-only) cells keep bit-identical
    results vs the dense-vmap oracle. a_scale (per-row activation stats,
    computed per expert slab exactly as the oracle computes them) is
    assembled the same way for the wide cells' requant."""
    mesh, ax, ns = ep.mesh, ep.axis, ep.size
    e, k, n = spec.experts, spec.in_dim, spec.out_dim
    e_loc = e // ns
    lead = x.shape[:-1]
    x3 = x.reshape(e, -1, k)
    m = x3.shape[-2]
    w_ops = _weight_ops(cell, op, p)
    shared = {nm: p[nm] for nm in ("a_scale",) if nm in p}
    use_pallas = op.backend == "pallas" and cell.body is not None
    tile = _resolve_tile(op)
    sub = dataclasses.replace(spec, experts=0)
    has_ascale = cell.aprec != "none"   # _prep_bf16 returns a_scale=None

    def local(x_loc, w_loc, sh):
        idx = jax.lax.axis_index(ax)
        x_ops, a_scale = jax.vmap(lambda x2d: cell.prep(x2d, sh, sub))(x_loc)
        if use_pallas:
            padm = (-m) % PAD_M
            if padm:
                x_ops = tuple(jnp.pad(v, ((0, 0), (0, padm), (0, 0)))
                              for v in x_ops)
            acc = harness.gemm_grouped(cell.body, x_ops, w_loc,
                                       k=k, tile=tile, out="acc",
                                       interpret=INTERPRET)[:, :m]
        else:
            acc = jax.vmap(lambda xo, wl: cell.acc(xo, wl, k))(x_ops, w_loc)

        def scatter(v):
            full = jnp.zeros((e,) + v.shape[1:], v.dtype)
            return jax.lax.dynamic_update_slice_in_dim(
                full, v, idx * e_loc, axis=0)

        # THE expert-parallel collective: disjoint-embedding psum (assembly)
        out = (scatter(acc), scatter(a_scale)) if has_ascale \
            else (scatter(acc),)
        return jax.tree.map(lambda v: jax.lax.psum(v, ax), out)

    wspecs = tuple(_ep_pspec(ep, nm, p[nm]) for nm in cell.weight_names)
    out_specs = (P(*([None] * x3.ndim)),)
    if has_ascale:
        out_specs = out_specs + (P(None, None),)
    res = _shard_map(local, mesh=mesh,
                     in_specs=(P(ax, None, None), wspecs,
                               {nm: P() for nm in shared}),
                     out_specs=out_specs)(x3, w_ops, shared)
    acc = res[0]
    a_scale = res[1] if has_ascale else None
    w_scale, bias = p.get("w_scale"), p.get("b")
    if cell.wide:
        rq = lambda a, ws, asc, b=None: harness.requant(a, ws, asc, b)
        if bias is not None:
            y = jax.vmap(rq)(acc, w_scale, a_scale, bias)
        else:
            y = jax.vmap(rq)(acc, w_scale, a_scale)
    else:
        rqn = lambda a, ws, b=None: _requant_narrow(a, ws, b)
        y = (jax.vmap(rqn)(acc, w_scale, bias) if bias is not None
             else jax.vmap(rqn)(acc, w_scale))
    return y.astype(jnp.bfloat16).reshape(*lead, n)


def tp_plan(cell: GemmCell, spec, parallel: str, tp: TPSpec | None) -> str | None:
    """Resolve the effective TP mode, or None => replicated fallback.

    Guards: the axis must exist with size > 1; column needs N % shards == 0;
    row needs a wide (integer-accumulator) cell and a K axis that splits into
    whole packed storage units per shard — `cell.k_quantum` is the pack
    factor (32-operand bit-plane words, 8-nibble s4 words, or 1 for int8)
    and `pack.shardable_words` the predicate, shared with the device-layout
    rules in launch.sharding so compute and placement agree.
    """
    if tp is None or parallel == "none":
        return None
    if parallel not in ("column", "row"):
        raise ValueError(f"parallel={parallel!r}")
    if tp.axis not in tp.mesh.axis_names:
        return None
    ns = tp.size
    if ns <= 1:
        return None
    if parallel == "column":
        return "column" if spec.out_dim % ns == 0 else None
    if not cell.wide:
        return None
    q = cell.k_quantum
    if spec.in_dim % q:
        return None
    return "row" if pack.shardable_words(spec.in_dim // q, ns) else None


def _weight_ops(cell: GemmCell, op: OperatingPoint, p: Mapping) -> tuple:
    """Fetch the cell's weight operands, applying the OperatingPoint's plane
    truncation (leading MSB-first slice; coefficients are positional, so the
    sliced stack needs no re-scaling). planes on a cell without a stacked
    leaf is a loud error — silently running full precision would make a
    draft pass lie about its cost."""
    w_ops = tuple(p[nm] for nm in cell.weight_names)
    if op.planes is None:
        return w_ops
    if "w_planes" not in cell.weight_names:
        raise ValueError(
            f"OperatingPoint planes={op.planes} needs a plane-composed cell; "
            f"{cell.key} has no stacked w_planes leaf")
    out = []
    for nm, wv in zip(cell.weight_names, w_ops):
        if nm == "w_planes":
            ax = wv.ndim - 3                 # plane axis (skips expert lead)
            if not 1 <= op.planes <= wv.shape[ax]:
                raise ValueError(
                    f"planes={op.planes} outside the stored stack depth "
                    f"{wv.shape[ax]} for {cell.key}")
            wv = jax.lax.slice_in_dim(wv, 0, op.planes, axis=ax)
        out.append(wv)
    return tuple(out)


def _dp_axis(tp: TPSpec, dim: int) -> str | None:
    """The single data axis of the mesh, when it divides `dim`."""
    dp = [a for a in tp.mesh.axis_names if a != tp.axis]
    if len(dp) == 1 and dim % int(tp.mesh.shape[dp[0]]) == 0:
        return dp[0]
    return None


def _tp_column(cell, p, x, spec, op, tp):
    """N-sharded qgemm: each shard runs the full plain path on its slice."""
    mesh, ax, ns = tp.mesh, tp.axis, tp.size
    sub = dataclasses.replace(spec, out_dim=spec.out_dim // ns)

    def pspec(nm, v):
        if v.ndim == 0 or nm not in _N_AXIS:
            return P(*([None] * v.ndim))
        dims = [None] * v.ndim
        dims[_N_AXIS[nm]] = ax
        return P(*dims)

    xdims = [None] * x.ndim
    odims = [None] * x.ndim
    dp = _dp_axis(tp, x.shape[0]) if (not spec.experts and x.ndim >= 2) else None
    if dp:
        xdims[0] = odims[0] = dp
    odims[-1] = ax
    pspecs = {nm: pspec(nm, v) for nm, v in p.items()}
    fn = lambda pl_, xl: qgemm(pl_, xl, sub, op)
    return _shard_map(fn, mesh=mesh, in_specs=(pspecs, P(*xdims)),
                      out_specs=P(*odims))(p, x)


def _tp_row(cell, p, x, spec, op, tp):
    """Packed-K-sharded qgemm: replicated full-K prep, per-shard integer
    partial dot, ONE int32 psum per call, deferred (global) requant."""
    mesh, ax, ns = tp.mesh, tp.axis, tp.size
    k, n = spec.in_dim, spec.out_dim
    lead = x.shape[:-1]
    e = spec.experts
    x3 = x.reshape((e, -1, k) if e else (-1, k))
    m = x3.shape[-2]
    w_ops = _weight_ops(cell, op, p)
    shared = {nm: p[nm] for nm in ("a_scale",) if nm in p}
    use_pallas = op.backend == "pallas" and cell.body is not None
    tile = _resolve_tile(op)
    k_loc = k // ns

    def wspec(nm):
        dims = [None] * p[nm].ndim
        dims[_K_AXIS[nm]] = ax
        return P(*dims)

    dp = None if e else _dp_axis(tp, m)
    xdims = [dp] + [None] * (x3.ndim - 1)
    accdims = list(xdims)           # acc: (E,) M, N — leading dims like x3
    asdims = xdims[:-1]             # a_scale: (E,) M

    def local(x_loc, w_loc, sh):
        idx = jax.lax.axis_index(ax)

        def one(x2d, wl):
            # full-K prep: per-row stats identical to the unsharded path.
            # Each prep output slices its OWN storage axis (mixed w/a cells
            # have different x/w densities, e.g. int8 codes vs trit words).
            x_ops, a_scale = cell.prep(x2d, sh, spec)
            xl = tuple(
                jax.lax.dynamic_slice_in_dim(
                    xo, idx * (xo.shape[-1] // ns), xo.shape[-1] // ns,
                    axis=-1) for xo in x_ops)
            if use_pallas:
                mm = x2d.shape[0]
                padm = (-mm) % PAD_M
                if padm:
                    xl = tuple(jnp.pad(v, ((0, padm), (0, 0))) for v in xl)
                acc = harness.gemm(cell.body, xl, wl, None, None, None,
                                   k=k_loc, tile=tile, out="acc",
                                   interpret=INTERPRET)[:mm]
            else:
                acc = cell.acc(xl, wl, k_loc)
            return acc, a_scale

        if e:
            acc, a_scale = jax.vmap(one)(x_loc, w_loc)
        else:
            acc, a_scale = one(x_loc, w_loc)
        # THE tensor-parallel collective: integer partial sums, pre-requant
        return jax.lax.psum(acc, ax), a_scale

    acc, a_scale = _shard_map(
        local, mesh=mesh,
        in_specs=(P(*xdims), tuple(wspec(nm) for nm in cell.weight_names),
                  {nm: P() for nm in shared}),
        out_specs=(P(*accdims), P(*asdims)))(x3, w_ops, shared)

    w_scale, bias = p.get("w_scale"), p.get("b")
    if e:
        rq = lambda a, ws, asc, b=None: harness.requant(a, ws, asc, b)
        y = (jax.vmap(rq)(acc, w_scale, a_scale, bias) if bias is not None
             else jax.vmap(rq)(acc, w_scale, a_scale))
    else:
        y = harness.requant(acc, w_scale, a_scale, bias)
    return y.astype(jnp.bfloat16).reshape(*lead, n)


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------

def _requant_narrow(acc, w_scale, bias):
    """Weight-only epilogue: scale in the accumulator dtype (bf16 TP wire),
    bias folded in f32 — the one place bias touches the narrow path."""
    y = acc if w_scale is None else acc * w_scale.astype(acc.dtype)
    if bias is not None:
        y = y.astype(jnp.float32) + bias
    return y


def qgemm(p: dict, x: jnp.ndarray, spec, op: OperatingPoint | None = None, *,
          tp: TPSpec | None = None, ep: "EPSpec | None" = None,
          parallel: str = "none",
          impl: str | None = None, backend: str | None = None) -> jnp.ndarray:
    """The serve-mode quantized GEMM: (..., K) -> (..., N) bf16.

    p: packed params from `core.qlinear.pack_params`; spec: QLinearSpec;
    op: the `OperatingPoint` to run — its wprec/aprec must match the spec's
    LayerQuant (the per-layer policy assignment), impl/backend select the
    formulation and where it executes, and tile (explicit, else the
    `TuneTable`) sets the Pallas block shapes. op=None derives the point
    from the spec plus the legacy `impl=`/`backend=` string kwargs (kept
    for out-of-tree callers; in-tree code passes `op`).

    backend="pallas" routes cells with a MacBody through `harness.gemm`
    (fused bias); backend="jnp" (and cells with no Pallas body) run the
    identical formulation via XLA. Both share prep and the requant algebra.

    tp + parallel ("column" | "row") run the GEMM under shard_map on the
    tensor-parallel mesh axis (see the TP section above): column shards N
    with no collective; row shards the packed K and psums the int32
    accumulator before requant. Both modes are bit-exact vs. the unsharded
    path; non-dividing shapes (and narrow-accumulator row cells) fall back
    to replicated compute — `tp_plan` is the single arbiter.

    ep (expert stacks only) runs the grouped expert dispatch: each shard
    computes only its local experts (see the EP section above). Checked
    before tp — when `ep_plan` declines (non-dividing expert count, dead
    axis) the call falls through to TP-within-expert, then the dense
    expert vmap, all bit-exact vs. each other.
    """
    if op is None:
        op = OperatingPoint.for_spec(spec, impl=impl or "popcount",
                                     backend=backend or "jnp")
    elif impl is not None or backend is not None:
        raise ValueError("pass either op= or the legacy impl=/backend= "
                         "kwargs, not both")
    if (op.wprec, op.aprec) != (spec.lq.weights.precision,
                                spec.lq.acts.precision):
        raise ValueError(
            f"OperatingPoint {op.tag} does not match the layer's policy "
            f"assignment {spec.lq.tag} for {spec.name!r}")
    cell = lookup(op)
    if spec.experts and ep is not None:
        plan = ep_plan(cell, spec, parallel, ep)
        if plan == "column":
            return _ep_column(cell, p, x, spec, op, ep)
        if plan == "row":
            return _ep_row(cell, p, x, spec, op, ep)
    if tp is not None and parallel != "none":
        plan = tp_plan(cell, spec, parallel, tp)
        if plan == "column":
            return _tp_column(cell, p, x, spec, op, tp)
        if plan == "row":
            return _tp_row(cell, p, x, spec, op, tp)
    if spec.experts:
        sub = dataclasses.replace(spec, experts=0)
        shared = {nm: p[nm] for nm in ("a_scale",) if nm in p}
        per_e = {nm: v for nm, v in p.items() if nm not in shared}
        fn = lambda pp, xx: qgemm({**pp, **shared}, xx, sub, op)
        return jax.vmap(fn)(per_e, x)

    k, n = spec.in_dim, spec.out_dim
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k)
    x_ops, a_scale = cell.prep(x2d, p, spec)
    w_ops = _weight_ops(cell, op, p)
    w_scale = p.get("w_scale")
    bias = p.get("b")

    if op.backend == "pallas" and cell.body is not None:
        m = x2d.shape[0]
        padm = (-m) % PAD_M
        if padm:
            x_ops = tuple(jnp.pad(xo, ((0, padm), (0, 0))) for xo in x_ops)
            a_scale = jnp.pad(a_scale, (0, padm))
        y = harness.gemm(cell.body, x_ops, w_ops, w_scale, a_scale, bias,
                         k=k, tile=_resolve_tile(op), interpret=INTERPRET)[:m]
    else:
        acc = cell.acc(x_ops, w_ops, k)
        if cell.wide:
            y = harness.requant(acc, w_scale, a_scale, bias)
        else:
            y = _requant_narrow(acc, w_scale, bias)
    return y.astype(jnp.bfloat16).reshape(*lead, n)


# ---------------------------------------------------------------------------
# CLI: the live registry as a table
# ---------------------------------------------------------------------------

def registry_table() -> str:
    """The registry rendered as an aligned text table (CI prints this)."""
    tune = default_tune()
    rows = [("wprec", "aprec", "impl", "backends", "weights", "acc",
             "tile(bm,bn,bkq)", "vmem")]
    for key in sorted(_REGISTRY):
        cell = _REGISTRY[key]
        backends = "jnp+pallas" if cell.body is not None else "jnp"
        tile = tune.tile_for(cell.op)
        if tile is None and cell.body is not None:
            tile = Tile(bkq=cell.body.default_bkq)
        tstr = f"{tile.bm},{tile.bn},{tile.bkq}" if tile else "-"
        vmem = (f"{harness.vmem_tile_bytes(cell.body, tile) / 2**10:.0f}KiB"
                if cell.body is not None else "-")
        rows.append((cell.wprec, cell.aprec, cell.impl, backends,
                     "+".join(cell.weight_names),
                     "int32" if cell.wide else "bf16", tstr, vmem))
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(v.ljust(w) for v, w in zip(r, widths)).rstrip()
             for r in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)


def _main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="qGEMM dispatch registry inspector")
    ap.add_argument("--list", action="store_true",
                    help="print the registered operating points as a table")
    args = ap.parse_args(argv)
    if args.list:
        print(f"# qgemm registry — {len(_REGISTRY)} cells "
              f"(tune: {default_tune().source or 'none'})")
        print(registry_table())
    else:
        ap.print_help()


if __name__ == "__main__":
    _main()
