"""Precision-keyed GEMM dispatch — the single entry point of the serve stack.

BrainTTA serves binary, ternary and int8 operands through one flexible
datapath (§III); this module is that datapath's software twin. Every serve
GEMM in the repo — `core.qlinear.apply(mode="serve")`, the Pallas backend
that used to live in `kernels.ops`, the launch drivers and the benches —
funnels through

    qgemm(p, x, spec, *, impl, backend)

which owns, exactly once, everything the four call sites used to copy:
activation quantization/packing, M-padding, block-size selection, expert
vmap, and the bias/requant epilogue (fused in-kernel on the Pallas backend,
single f32 requant on the jnp backend — no separate bias round-trip).

The registry maps operating points (wprec, aprec, impl) to `GemmCell`s.
Each cell holds the ONE implementation of its formulation:

  prep  — activation quantize/pack (shared verbatim by both backends, so
          jnp-vs-pallas equivalence is an algebra check, not a tolerance
          dance)
  acc   — the jnp accumulator formulation (XLA backend / CPU dry-run)
  body  — the Pallas `MacBody` riding `harness.gemm`'s shared skeleton
          (None = no packed kernel; the jnp formulation serves both
          backends, e.g. the weight-only cells whose activations stay bf16
          on the MXU — quantizing them here would silently change the
          algebra vs QAT)

Adding a precision or kernel variant = one prep/acc/body triple + one
`register()` call. `impl="*"` marks formulation-agnostic cells (int8 has no
popcount/mxu split; weight-only cells ignore impl).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import pack
from repro.core.quantize import int8_codes, ternarize

from . import bgemm, i8gemm, tgemm
from . import harness

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"

#: Pallas kernels need M padded to the sublane multiple.
PAD_M = 8


@dataclasses.dataclass(frozen=True)
class GemmCell:
    """One (wprec, aprec, impl) operating point of the datapath."""
    wprec: str
    aprec: str
    impl: str                       # "popcount" | "mxu" | "*" (agnostic)
    weight_names: tuple[str, ...]   # packed-param entries feeding the GEMM
    prep: Callable                  # (x2d, p, spec) -> (x_ops, a_scale|None)
    acc: Callable                   # (x_ops, w_ops, k) -> (M, N) accumulator
    body: harness.MacBody | None = None   # Pallas tile body (None = jnp only)
    wide: bool = True               # f32 requant (W&A) vs bf16 (weight-only)

    @property
    def key(self) -> tuple[str, str, str]:
        return (self.wprec, self.aprec, self.impl)

    @property
    def tag(self) -> str:
        return f"w{self.wprec[:3]}/a{self.aprec[:3]}/{self.impl}"


_REGISTRY: dict[tuple[str, str, str], GemmCell] = {}


def register(cell: GemmCell) -> GemmCell:
    if cell.key in _REGISTRY:
        raise ValueError(f"duplicate GEMM registration for {cell.key}")
    _REGISTRY[cell.key] = cell
    return cell


def lookup(wprec: str, aprec: str, impl: str = "popcount") -> GemmCell:
    """Resolve an operating point; impl falls back to a '*' cell."""
    for key in ((wprec, aprec, impl), (wprec, aprec, "*")):
        if key in _REGISTRY:
            return _REGISTRY[key]
    raise KeyError(
        f"no GEMM registered for (wprec={wprec!r}, aprec={aprec!r}, "
        f"impl={impl!r}); have {sorted(_REGISTRY)}")


def cells() -> dict[tuple[str, str, str], GemmCell]:
    """Snapshot of the registry (tests / benches iterate this)."""
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# activation prep — ONE quantize+pack per activation precision
# ---------------------------------------------------------------------------

def _prep_binary(x2d, p, spec):
    xf = x2d.astype(jnp.float32)
    a_scale = jnp.mean(jnp.abs(xf), axis=-1)          # XNOR-Net per-row alpha
    xp = pack.pack_binary(jnp.where(xf >= 0, 1.0, -1.0))
    return (xp,), a_scale


def _prep_ternary(x2d, p, spec):
    xf = x2d.astype(jnp.float32)
    a_scale = jnp.mean(jnp.abs(xf), axis=-1)
    # per-row threshold (axis=-1): under continuous batching a per-tensor
    # threshold couples co-batched requests — one slot's activations would
    # move every other slot's ternarization cut
    xq = jax.lax.stop_gradient(
        ternarize(xf, spec.lq.acts.ternary_threshold, axis=-1))
    xm, xs = pack.pack_ternary(xq)
    return (xm, xs), a_scale


def _prep_int8(x2d, p, spec):
    a_s = p["a_scale"]     # calibrated constant; KeyError = packing bug,
    xq = int8_codes(x2d.astype(jnp.float32), a_s)  # not a default to paper over
    return (xq,), jnp.full((x2d.shape[0],), a_s, jnp.float32)


def _prep_bf16(x2d, p, spec):
    """Weight-only / dense: activations stay bf16 (MXU path)."""
    return (x2d.astype(jnp.bfloat16),), None


# ---------------------------------------------------------------------------
# jnp accumulator formulations — ONE per registered cell
# ---------------------------------------------------------------------------

def _acc_binary_popcount(x_ops, w_ops, k):
    return pack.binary_dot_words(x_ops[0][:, None, :], w_ops[0], k)


def _acc_binary_mxu(x_ops, w_ops, k):
    x = pack.unpack_pm1_i8(x_ops[0], k)                # (M, K) ±1 int8
    w = pack.unpack_pm1_i8(w_ops[0], k)                # (N, K)
    return jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_ternary_popcount(x_ops, w_ops, k):
    return pack.ternary_dot_words(x_ops[0][:, None, :], x_ops[1][:, None, :],
                                  w_ops[0], w_ops[1])


def _acc_ternary_mxu(x_ops, w_ops, k):
    x = pack.unpack_ternary_i8(x_ops[0], x_ops[1], k)  # (M, K) trits int8
    w = pack.unpack_ternary_i8(w_ops[0], w_ops[1], k)  # (N, K)
    return jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_int8(x_ops, w_ops, k):
    return jax.lax.dot_general(x_ops[0], w_ops[0], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_wonly_binary(x_ops, w_ops, k):
    w = pack.unpack_pm1_i8(w_ops[0], k)                # (N, K)
    return x_ops[0] @ w.astype(x_ops[0].dtype).T


def _acc_wonly_ternary(x_ops, w_ops, k):
    w = pack.unpack_ternary_i8(w_ops[0], w_ops[1], k)
    return x_ops[0] @ w.astype(x_ops[0].dtype).T


def _acc_wonly_int8(x_ops, w_ops, k):
    return x_ops[0] @ w_ops[0].astype(x_ops[0].dtype)  # w_q is (K, N)


def _acc_dense(x_ops, w_ops, k):
    return x_ops[0] @ w_ops[0]


# ---------------------------------------------------------------------------
# the registry — every operating point of the POLICIES table
# ---------------------------------------------------------------------------

# W&A-quantized cells: packed operands, int accumulators, Pallas bodies.
register(GemmCell("binary", "binary", "popcount", ("w_packed",),
                  _prep_binary, _acc_binary_popcount, body=bgemm.BINARY_POPCOUNT))
register(GemmCell("binary", "binary", "mxu", ("w_packed",),
                  _prep_binary, _acc_binary_mxu, body=bgemm.BINARY_MXU))
register(GemmCell("ternary", "ternary", "popcount", ("w_mask", "w_sign"),
                  _prep_ternary, _acc_ternary_popcount,
                  body=tgemm.TERNARY_POPCOUNT))
register(GemmCell("ternary", "ternary", "mxu", ("w_mask", "w_sign"),
                  _prep_ternary, _acc_ternary_mxu, body=tgemm.TERNARY_MXU))
register(GemmCell("int8", "int8", "*", ("w_q",),
                  _prep_int8, _acc_int8, body=i8gemm.I8_DOT))

# weight-only cells: bf16 acts end-to-end so the row-parallel TP partial-sum
# reduces in bf16 (2x wire, §Perf A); requant stays in bf16 (wide=False).
register(GemmCell("binary", "none", "*", ("w_packed",),
                  _prep_bf16, _acc_wonly_binary, wide=False))
register(GemmCell("ternary", "none", "*", ("w_mask", "w_sign"),
                  _prep_bf16, _acc_wonly_ternary, wide=False))
register(GemmCell("int8", "none", "*", ("w_q",),
                  _prep_bf16, _acc_wonly_int8, wide=False))
register(GemmCell("none", "none", "*", ("w",),
                  _prep_bf16, _acc_dense, wide=False))


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------

def _requant_narrow(acc, w_scale, bias):
    """Weight-only epilogue: scale in the accumulator dtype (bf16 TP wire),
    bias folded in f32 — the one place bias touches the narrow path."""
    y = acc if w_scale is None else acc * w_scale.astype(acc.dtype)
    if bias is not None:
        y = y.astype(jnp.float32) + bias
    return y


def qgemm(p: dict, x: jnp.ndarray, spec, *, impl: str = "popcount",
          backend: str = "jnp") -> jnp.ndarray:
    """The serve-mode quantized GEMM: (..., K) -> (..., N) bf16.

    p: packed params from `core.qlinear.pack_params`; spec: QLinearSpec.
    backend="pallas" routes W&A cells through `harness.gemm` (fused bias);
    backend="jnp" (and cells with no Pallas body) run the identical
    formulation via XLA. Both share prep and the requant algebra.
    """
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"backend={backend!r}")
    if spec.experts:
        sub = dataclasses.replace(spec, experts=0)
        shared = {nm: p[nm] for nm in ("a_scale",) if nm in p}
        per_e = {nm: v for nm, v in p.items() if nm not in shared}
        fn = lambda pp, xx: qgemm({**pp, **shared}, xx, sub,
                                  impl=impl, backend=backend)
        return jax.vmap(fn)(per_e, x)

    cell = lookup(spec.lq.weights.precision, spec.lq.acts.precision, impl)
    k, n = spec.in_dim, spec.out_dim
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k)
    x_ops, a_scale = cell.prep(x2d, p, spec)
    w_ops = tuple(p[nm] for nm in cell.weight_names)
    w_scale = p.get("w_scale")
    bias = p.get("b")

    if backend == "pallas" and cell.body is not None:
        m = x2d.shape[0]
        padm = (-m) % PAD_M
        if padm:
            x_ops = tuple(jnp.pad(xo, ((0, padm), (0, 0))) for xo in x_ops)
            a_scale = jnp.pad(a_scale, (0, padm))
        y = harness.gemm(cell.body, x_ops, w_ops, w_scale, a_scale, bias,
                         k=k, interpret=INTERPRET)[:m]
    else:
        acc = cell.acc(x_ops, w_ops, k)
        if cell.wide:
            y = harness.requant(acc, w_scale, a_scale, bias)
        else:
            y = _requant_narrow(acc, w_scale, bias)
    return y.astype(jnp.bfloat16).reshape(*lead, n)
