"""Precision-keyed GEMM dispatch — the single entry point of the serve stack.

BrainTTA serves binary, ternary and int8 operands through one flexible
datapath (§III); this module is that datapath's software twin. Every serve
GEMM in the repo — `core.qlinear.apply(mode="serve")`, the Pallas backend
that used to live in `kernels.ops`, the launch drivers and the benches —
funnels through

    qgemm(p, x, spec, *, impl, backend)

which owns, exactly once, everything the four call sites used to copy:
activation quantization/packing, M-padding, block-size selection, expert
vmap, and the bias/requant epilogue (fused in-kernel on the Pallas backend,
single f32 requant on the jnp backend — no separate bias round-trip).

The registry maps operating points (wprec, aprec, impl) to `GemmCell`s.
Each cell holds the ONE implementation of its formulation:

  prep  — activation quantize/pack (shared verbatim by both backends, so
          jnp-vs-pallas equivalence is an algebra check, not a tolerance
          dance)
  acc   — the jnp accumulator formulation (XLA backend / CPU dry-run)
  body  — the Pallas `MacBody` riding `harness.gemm`'s shared skeleton
          (None = no packed kernel; the jnp formulation serves both
          backends, e.g. the weight-only cells whose activations stay bf16
          on the MXU — quantizing them here would silently change the
          algebra vs QAT)

Adding a precision or kernel variant = one prep/acc/body triple + one
`register()` call. `impl="*"` marks formulation-agnostic cells (int8 has no
popcount/mxu split; weight-only cells ignore impl).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import pack
from repro.core.quantize import int8_codes, ternarize

from . import bgemm, i8gemm, tgemm
from . import harness

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"

#: Pallas kernels need M padded to the sublane multiple.
PAD_M = 8


@dataclasses.dataclass(frozen=True)
class GemmCell:
    """One (wprec, aprec, impl) operating point of the datapath."""
    wprec: str
    aprec: str
    impl: str                       # "popcount" | "mxu" | "*" (agnostic)
    weight_names: tuple[str, ...]   # packed-param entries feeding the GEMM
    prep: Callable                  # (x2d, p, spec) -> (x_ops, a_scale|None)
    acc: Callable                   # (x_ops, w_ops, k) -> (M, N) accumulator
    body: harness.MacBody | None = None   # Pallas tile body (None = jnp only)
    wide: bool = True               # f32 requant (W&A) vs bf16 (weight-only)

    @property
    def key(self) -> tuple[str, str, str]:
        return (self.wprec, self.aprec, self.impl)

    @property
    def tag(self) -> str:
        return f"w{self.wprec[:3]}/a{self.aprec[:3]}/{self.impl}"


_REGISTRY: dict[tuple[str, str, str], GemmCell] = {}


def register(cell: GemmCell) -> GemmCell:
    if cell.key in _REGISTRY:
        raise ValueError(f"duplicate GEMM registration for {cell.key}")
    _REGISTRY[cell.key] = cell
    return cell


def lookup(wprec: str, aprec: str, impl: str = "popcount") -> GemmCell:
    """Resolve an operating point; impl falls back to a '*' cell."""
    for key in ((wprec, aprec, impl), (wprec, aprec, "*")):
        if key in _REGISTRY:
            return _REGISTRY[key]
    raise KeyError(
        f"no GEMM registered for (wprec={wprec!r}, aprec={aprec!r}, "
        f"impl={impl!r}); have {sorted(_REGISTRY)}")


def cells() -> dict[tuple[str, str, str], GemmCell]:
    """Snapshot of the registry (tests / benches iterate this)."""
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# activation prep — ONE quantize+pack per activation precision
# ---------------------------------------------------------------------------

def _prep_binary(x2d, p, spec):
    xf = x2d.astype(jnp.float32)
    a_scale = jnp.mean(jnp.abs(xf), axis=-1)          # XNOR-Net per-row alpha
    xp = pack.pack_binary(jnp.where(xf >= 0, 1.0, -1.0))
    return (xp,), a_scale


def _prep_ternary(x2d, p, spec):
    xf = x2d.astype(jnp.float32)
    a_scale = jnp.mean(jnp.abs(xf), axis=-1)
    # per-row threshold (axis=-1): under continuous batching a per-tensor
    # threshold couples co-batched requests — one slot's activations would
    # move every other slot's ternarization cut
    xq = jax.lax.stop_gradient(
        ternarize(xf, spec.lq.acts.ternary_threshold, axis=-1))
    xm, xs = pack.pack_ternary(xq)
    return (xm, xs), a_scale


def _prep_int8(x2d, p, spec):
    a_s = p["a_scale"]     # calibrated constant; KeyError = packing bug,
    xq = int8_codes(x2d.astype(jnp.float32), a_s)  # not a default to paper over
    return (xq,), jnp.full((x2d.shape[0],), a_s, jnp.float32)


def _prep_bf16(x2d, p, spec):
    """Weight-only / dense: activations stay bf16 (MXU path)."""
    return (x2d.astype(jnp.bfloat16),), None


# ---------------------------------------------------------------------------
# jnp accumulator formulations — ONE per registered cell
# ---------------------------------------------------------------------------

def _acc_binary_popcount(x_ops, w_ops, k):
    return pack.binary_dot_words(x_ops[0][:, None, :], w_ops[0], k)


def _acc_binary_mxu(x_ops, w_ops, k):
    x = pack.unpack_pm1_i8(x_ops[0], k)                # (M, K) ±1 int8
    w = pack.unpack_pm1_i8(w_ops[0], k)                # (N, K)
    return jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_ternary_popcount(x_ops, w_ops, k):
    return pack.ternary_dot_words(x_ops[0][:, None, :], x_ops[1][:, None, :],
                                  w_ops[0], w_ops[1])


def _acc_ternary_mxu(x_ops, w_ops, k):
    x = pack.unpack_ternary_i8(x_ops[0], x_ops[1], k)  # (M, K) trits int8
    w = pack.unpack_ternary_i8(w_ops[0], w_ops[1], k)  # (N, K)
    return jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_int8(x_ops, w_ops, k):
    return jax.lax.dot_general(x_ops[0], w_ops[0], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _acc_wonly_binary(x_ops, w_ops, k):
    w = pack.unpack_pm1_i8(w_ops[0], k)                # (N, K)
    return x_ops[0] @ w.astype(x_ops[0].dtype).T


def _acc_wonly_ternary(x_ops, w_ops, k):
    w = pack.unpack_ternary_i8(w_ops[0], w_ops[1], k)
    return x_ops[0] @ w.astype(x_ops[0].dtype).T


def _acc_wonly_int8(x_ops, w_ops, k):
    return x_ops[0] @ w_ops[0].astype(x_ops[0].dtype)  # w_q is (K, N)


def _acc_dense(x_ops, w_ops, k):
    return x_ops[0] @ w_ops[0]


# ---------------------------------------------------------------------------
# the registry — every operating point of the POLICIES table
# ---------------------------------------------------------------------------

# W&A-quantized cells: packed operands, int accumulators, Pallas bodies.
register(GemmCell("binary", "binary", "popcount", ("w_packed",),
                  _prep_binary, _acc_binary_popcount, body=bgemm.BINARY_POPCOUNT))
register(GemmCell("binary", "binary", "mxu", ("w_packed",),
                  _prep_binary, _acc_binary_mxu, body=bgemm.BINARY_MXU))
register(GemmCell("ternary", "ternary", "popcount", ("w_mask", "w_sign"),
                  _prep_ternary, _acc_ternary_popcount,
                  body=tgemm.TERNARY_POPCOUNT))
register(GemmCell("ternary", "ternary", "mxu", ("w_mask", "w_sign"),
                  _prep_ternary, _acc_ternary_mxu, body=tgemm.TERNARY_MXU))
register(GemmCell("int8", "int8", "*", ("w_q",),
                  _prep_int8, _acc_int8, body=i8gemm.I8_DOT))

# weight-only cells: bf16 acts end-to-end so the row-parallel TP partial-sum
# reduces in bf16 (2x wire, §Perf A); requant stays in bf16 (wide=False).
register(GemmCell("binary", "none", "*", ("w_packed",),
                  _prep_bf16, _acc_wonly_binary, wide=False))
register(GemmCell("ternary", "none", "*", ("w_mask", "w_sign"),
                  _prep_bf16, _acc_wonly_ternary, wide=False))
register(GemmCell("int8", "none", "*", ("w_q",),
                  _prep_bf16, _acc_wonly_int8, wide=False))
register(GemmCell("none", "none", "*", ("w",),
                  _prep_bf16, _acc_dense, wide=False))


# ---------------------------------------------------------------------------
# tensor parallelism: qgemm under shard_map
# ---------------------------------------------------------------------------
#
# Megatron pairing over the ("data", "model") mesh:
#
#   column-parallel (qkv/up):  weights N-sharded over the model axis; every
#       shard sees the full K, runs the COMPLETE plain qgemm (prep + acc +
#       requant) on its N slice — no collective, bit-exact per slice.
#   row-parallel (out/down):   packed K-sharded. Activation prep (per-row
#       alpha / trit threshold / int8 codes) runs REPLICATED inside the
#       shard_map body on the full K — the per-row statistics must see every
#       K element, and computing them on the full row keeps the algebra
#       identical to the single-device path. Each shard then slices its own
#       packed-K chunk, accumulates its partial dot, and the partials are
#       psum'd on the int32 accumulator BEFORE requant: integer addition is
#       associative, so the TP sum is bit-exact; requantizing per-shard
#       partials would be numerically wrong (and f32/bf16 psum inexact).
#
# Weight-only cells (wide=False) keep bf16 accumulators — a narrow psum is
# NOT bit-exact, so row-parallel falls back to replicated compute for them
# (column-parallel still shards: it needs no collective). The batch/M dim
# additionally shards over the "data" axis when it divides.

def _shard_map(f, *, mesh, in_specs, out_specs):
    """The repo's one version-tolerant shard_map (optim.compress owns it),
    with replication checking off: Pallas calls inside the body have no
    replication rule on older jax."""
    from repro.optim.compress import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


@dataclasses.dataclass(frozen=True)
class TPSpec:
    """Tensor-parallel context threaded from the serve driver into qgemm."""
    mesh: Any                       # jax.sharding.Mesh
    axis: str = "model"             # TP (contraction/out-dim) axis name

    @property
    def size(self) -> int:
        return int(self.mesh.shape[self.axis])


#: per-leaf axis positions (negative = from the end; leading expert axis ok)
_N_AXIS = {"w_packed": -2, "w_mask": -2, "w_sign": -2,
           "w_q": -1, "w": -1, "w_scale": -1, "b": -1}
_K_AXIS = {"w_packed": -1, "w_mask": -1, "w_sign": -1, "w_q": -2, "w": -2}
_PACKED_NAMES = ("w_packed", "w_mask", "w_sign")


def tp_plan(cell: GemmCell, spec, parallel: str, tp: TPSpec | None) -> str | None:
    """Resolve the effective TP mode, or None => replicated fallback.

    Guards: the axis must exist with size > 1; column needs N % shards == 0;
    row needs a wide (integer-accumulator) cell and a K axis that splits into
    whole packed words per shard (`pack.shardable_words` — shared with the
    device-layout rules in launch.sharding so compute and placement agree).
    """
    if tp is None or parallel == "none":
        return None
    if parallel not in ("column", "row"):
        raise ValueError(f"parallel={parallel!r}")
    if tp.axis not in tp.mesh.axis_names:
        return None
    ns = tp.size
    if ns <= 1:
        return None
    if parallel == "column":
        return "column" if spec.out_dim % ns == 0 else None
    if not cell.wide:
        return None
    packed = any(nm in _PACKED_NAMES for nm in cell.weight_names)
    units = spec.in_dim // pack.WORD if packed else spec.in_dim
    if packed and spec.in_dim % pack.WORD:
        return None
    return "row" if pack.shardable_words(units, ns) else None


def _dp_axis(tp: TPSpec, dim: int) -> str | None:
    """The single data axis of the mesh, when it divides `dim`."""
    dp = [a for a in tp.mesh.axis_names if a != tp.axis]
    if len(dp) == 1 and dim % int(tp.mesh.shape[dp[0]]) == 0:
        return dp[0]
    return None


def _tp_column(cell, p, x, spec, impl, backend, tp):
    """N-sharded qgemm: each shard runs the full plain path on its slice."""
    mesh, ax, ns = tp.mesh, tp.axis, tp.size
    sub = dataclasses.replace(spec, out_dim=spec.out_dim // ns)

    def pspec(nm, v):
        if v.ndim == 0 or nm not in _N_AXIS:
            return P(*([None] * v.ndim))
        dims = [None] * v.ndim
        dims[_N_AXIS[nm]] = ax
        return P(*dims)

    xdims = [None] * x.ndim
    odims = [None] * x.ndim
    dp = _dp_axis(tp, x.shape[0]) if (not spec.experts and x.ndim >= 2) else None
    if dp:
        xdims[0] = odims[0] = dp
    odims[-1] = ax
    pspecs = {nm: pspec(nm, v) for nm, v in p.items()}
    fn = lambda pl_, xl: qgemm(pl_, xl, sub, impl=impl, backend=backend)
    return _shard_map(fn, mesh=mesh, in_specs=(pspecs, P(*xdims)),
                      out_specs=P(*odims))(p, x)


def _tp_row(cell, p, x, spec, impl, backend, tp):
    """Packed-K-sharded qgemm: replicated full-K prep, per-shard integer
    partial dot, ONE int32 psum per call, deferred (global) requant."""
    mesh, ax, ns = tp.mesh, tp.axis, tp.size
    k, n = spec.in_dim, spec.out_dim
    lead = x.shape[:-1]
    e = spec.experts
    x3 = x.reshape((e, -1, k) if e else (-1, k))
    m = x3.shape[-2]
    w_ops = tuple(p[nm] for nm in cell.weight_names)
    shared = {nm: p[nm] for nm in ("a_scale",) if nm in p}
    use_pallas = backend == "pallas" and cell.body is not None
    k_loc = k // ns

    def wspec(nm):
        dims = [None] * p[nm].ndim
        dims[_K_AXIS[nm]] = ax
        return P(*dims)

    dp = None if e else _dp_axis(tp, m)
    xdims = [dp] + [None] * (x3.ndim - 1)
    accdims = list(xdims)           # acc: (E,) M, N — leading dims like x3
    asdims = xdims[:-1]             # a_scale: (E,) M

    def local(x_loc, w_loc, sh):
        idx = jax.lax.axis_index(ax)

        def one(x2d, wl):
            # full-K prep: per-row stats identical to the unsharded path
            x_ops, a_scale = cell.prep(x2d, sh, spec)
            kq_loc = x_ops[0].shape[-1] // ns
            xl = tuple(jax.lax.dynamic_slice_in_dim(xo, idx * kq_loc, kq_loc,
                                                    axis=-1) for xo in x_ops)
            if use_pallas:
                mm = x2d.shape[0]
                padm = (-mm) % PAD_M
                if padm:
                    xl = tuple(jnp.pad(v, ((0, padm), (0, 0))) for v in xl)
                acc = harness.gemm(cell.body, xl, wl, None, None, None,
                                   k=k_loc, out="acc", interpret=INTERPRET)[:mm]
            else:
                acc = cell.acc(xl, wl, k_loc)
            return acc, a_scale

        if e:
            acc, a_scale = jax.vmap(one)(x_loc, w_loc)
        else:
            acc, a_scale = one(x_loc, w_loc)
        # THE tensor-parallel collective: integer partial sums, pre-requant
        return jax.lax.psum(acc, ax), a_scale

    acc, a_scale = _shard_map(
        local, mesh=mesh,
        in_specs=(P(*xdims), tuple(wspec(nm) for nm in cell.weight_names),
                  {nm: P() for nm in shared}),
        out_specs=(P(*accdims), P(*asdims)))(x3, w_ops, shared)

    w_scale, bias = p.get("w_scale"), p.get("b")
    if e:
        rq = lambda a, ws, asc, b=None: harness.requant(a, ws, asc, b)
        y = (jax.vmap(rq)(acc, w_scale, a_scale, bias) if bias is not None
             else jax.vmap(rq)(acc, w_scale, a_scale))
    else:
        y = harness.requant(acc, w_scale, a_scale, bias)
    return y.astype(jnp.bfloat16).reshape(*lead, n)


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------

def _requant_narrow(acc, w_scale, bias):
    """Weight-only epilogue: scale in the accumulator dtype (bf16 TP wire),
    bias folded in f32 — the one place bias touches the narrow path."""
    y = acc if w_scale is None else acc * w_scale.astype(acc.dtype)
    if bias is not None:
        y = y.astype(jnp.float32) + bias
    return y


def qgemm(p: dict, x: jnp.ndarray, spec, *, impl: str = "popcount",
          backend: str = "jnp", tp: TPSpec | None = None,
          parallel: str = "none") -> jnp.ndarray:
    """The serve-mode quantized GEMM: (..., K) -> (..., N) bf16.

    p: packed params from `core.qlinear.pack_params`; spec: QLinearSpec.
    backend="pallas" routes W&A cells through `harness.gemm` (fused bias);
    backend="jnp" (and cells with no Pallas body) run the identical
    formulation via XLA. Both share prep and the requant algebra.

    tp + parallel ("column" | "row") run the GEMM under shard_map on the
    tensor-parallel mesh axis (see the TP section above): column shards N
    with no collective; row shards the packed K and psums the int32
    accumulator before requant. Both modes are bit-exact vs. the unsharded
    path; non-dividing shapes (and narrow-accumulator row cells) fall back
    to replicated compute — `tp_plan` is the single arbiter.
    """
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"backend={backend!r}")
    if tp is not None and parallel != "none":
        cell = lookup(spec.lq.weights.precision, spec.lq.acts.precision, impl)
        plan = tp_plan(cell, spec, parallel, tp)
        if plan == "column":
            return _tp_column(cell, p, x, spec, impl, backend, tp)
        if plan == "row":
            return _tp_row(cell, p, x, spec, impl, backend, tp)
    if spec.experts:
        sub = dataclasses.replace(spec, experts=0)
        shared = {nm: p[nm] for nm in ("a_scale",) if nm in p}
        per_e = {nm: v for nm, v in p.items() if nm not in shared}
        fn = lambda pp, xx: qgemm({**pp, **shared}, xx, sub,
                                  impl=impl, backend=backend)
        return jax.vmap(fn)(per_e, x)

    cell = lookup(spec.lq.weights.precision, spec.lq.acts.precision, impl)
    k, n = spec.in_dim, spec.out_dim
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k)
    x_ops, a_scale = cell.prep(x2d, p, spec)
    w_ops = tuple(p[nm] for nm in cell.weight_names)
    w_scale = p.get("w_scale")
    bias = p.get("b")

    if backend == "pallas" and cell.body is not None:
        m = x2d.shape[0]
        padm = (-m) % PAD_M
        if padm:
            x_ops = tuple(jnp.pad(xo, ((0, padm), (0, 0))) for xo in x_ops)
            a_scale = jnp.pad(a_scale, (0, padm))
        y = harness.gemm(cell.body, x_ops, w_ops, w_scale, a_scale, bias,
                         k=k, interpret=INTERPRET)[:m]
    else:
        acc = cell.acc(x_ops, w_ops, k)
        if cell.wide:
            y = harness.requant(acc, w_scale, a_scale, bias)
        else:
            y = _requant_narrow(acc, w_scale, bias)
    return y.astype(jnp.bfloat16).reshape(*lead, n)
