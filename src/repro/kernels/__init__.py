"""Pallas TPU kernels for BrainTTA's compute hot-spot: the mixed-precision GEMM.

harness  — the ONE output-stationary tiled skeleton (grid, BlockSpecs, VMEM
           accumulators, fused requant epilogue) every precision rides
dispatch — OperatingPoint-keyed registry + `qgemm`, the single serve entry
           point, plus the per-cell `TuneTable` block-shape data
bgemm    — binary XNOR+popcount (vBMAC) + beyond-paper MXU MacBodies
tgemm    — ternary gated-XNOR (vTMAC) + MXU + mixed w-ternary×a-int8 bodies
i8gemm   — int8 MXU dot MacBody (8-bit vMAC)
i4gemm   — int4 (s4 nibble) × int8 MacBody (W4A8)
ref      — pure-jnp oracles.
"""
from . import bgemm, dispatch, harness, i4gemm, i8gemm, ref, tgemm  # noqa: F401
from . import flash_attn  # noqa: F401
from .dispatch import OperatingPoint, Tile, TuneTable, qgemm  # noqa: F401
