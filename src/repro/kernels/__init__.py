"""Pallas TPU kernels for BrainTTA's compute hot-spot: the mixed-precision GEMM.

harness  — the ONE output-stationary tiled skeleton (grid, BlockSpecs, VMEM
           accumulators, fused requant epilogue) every precision rides
dispatch — precision-keyed registry + `qgemm`, the single serve entry point
bgemm    — binary XNOR+popcount (vBMAC) + beyond-paper MXU MacBodies
tgemm    — ternary gated-XNOR (vTMAC) + MXU MacBodies
i8gemm   — int8 MXU dot MacBody (8-bit vMAC)
ops      — compat shim over dispatch; ref — pure-jnp oracles.
"""
from . import bgemm, dispatch, harness, i8gemm, ops, ref, tgemm  # noqa: F401
from . import flash_attn  # noqa: F401
from .dispatch import qgemm  # noqa: F401
