"""Pallas TPU kernels for BrainTTA's compute hot-spot: the mixed-precision GEMM.

bgemm — binary XNOR+popcount (vBMAC), + beyond-paper MXU variant
tgemm — ternary gated-XNOR+popcount (vTMAC)
i8gemm — int8 MXU GEMM with fused requant epilogue (8-bit vMAC)
ops   — jit'd model-facing wrappers; ref — pure-jnp oracles.
"""
from . import bgemm, i8gemm, ops, ref, tgemm  # noqa: F401
from . import flash_attn  # noqa: F401
