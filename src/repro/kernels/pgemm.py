"""Plane-composed MAC bodies — int4/int8 weights as shifted binary planes.

BrainTTA's flexible datapath spans binary..int8 through ONE MAC array; the
weight-combination line (arXiv 2502.00687, and the Molendijk/Corporaal
mixed-precision survey) closes the loop in the other direction: a b-bit
weight is an exact shifted sum of b binary planes, so the binary datapath
serves every precision by looping planes. `core.pack.pack_planes` stores
int4/int8 codes as a stacked (b, N, K/32) uint32 tensor (MSB-first two's
complement, plane 0 = sign plane with coefficient -2^(b-1)); the step below
unpacks one plane at a time to {0,1} int8 *in VMEM*, rides the int8 MXU, and
folds the plane coefficient into the int32 accumulator:

    acc += coeff_i * (x . bits_i)        coeff_i from pack.plane_coeffs(b)

All arithmetic is integer, so the composed dot is bit-identical to the
direct int4/int8 cells (and to the dequantize-then-fp32 oracle) after the
shared requant epilogue. HBM traffic stays bit-plane packed.

The live plane depth is the operand's leading axis (static per trace): a
truncated stack `w_planes[:P]` — the self-speculative *draft* configuration
— runs the same body over fewer planes with UNCHANGED coefficients, i.e.
floor-truncated weights, at P/b of the MAC work.

Registration into the serve stack lives in `repro.kernels.dispatch`
(operating points int4 x int8 and int8 x int8, impl="planes").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import pack

from .harness import MacBody


def _planes_step(xs, ws, accs, *, bkq, bits):
    k = bkq * pack.WORD
    wp = ws[0]                                  # (P, bn, bkq) uint32 planes
    x = xs[0]                                   # (bm, k) int8 act codes
    acc = accs[0]
    coeffs = pack.plane_coeffs(bits)            # python ints: static in trace
    for i in range(wp.shape[0]):                # live depth, unrolled
        bits_i = pack.unpack_bits(wp[i], k).astype(jnp.int8)   # (bn, k) {0,1}
        dot = jax.lax.dot_general(x, bits_i, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        acc = acc + jnp.int32(coeffs[i]) * dot
    return (acc,)


def _mk(bits: int, name: str) -> MacBody:
    return MacBody(name, n_x=1, n_w=1, n_acc=1,
                   k_per_q=pack.WORD, xk_per_q=1, wk_per_q=pack.WORD,
                   step=functools.partial(_planes_step, bits=bits),
                   finish=lambda accs, k: accs[0],
                   unpacks_i8=True, default_bkq=8, w_stack=bits)


PLANES_W4_I8A = _mk(4, "pgemm_w4a8_planes")
PLANES_W8_I8A = _mk(8, "pgemm_w8a8_planes")
