"""Paged-attention decode Pallas kernel — flash-decode over the page table.

The serve stack's decode attention (`models.attention.attn_decode(pages=)`)
reads a slot's KV through a (B, max_pages) page table into the shared block
pool (launch/kv_cache.py). The jnp oracle path gathers every page into a
dense (B, max_pages*page_size, Hk, dh) view and runs dense attention — each
decode step materializes (and dequantizes) the whole per-slot pool footprint
regardless of the slot's actual length. BrainTTA's thesis (and the
operand-fetch argument of the Molendijk/Corporaal survey) is that the data
movement belongs *inside* the compute loop; this kernel is that move for
decode:

  grid (slot, kv-page-block), page-block innermost (output-stationary in the
  slot). The page table and per-slot positions ride in as scalar-prefetch
  operands; each active step walks `pages[b, j*bkp : (j+1)*bkp]` and DMAs
  those pages' K/V tiles from the pool (left in ANY/HBM memory space) into
  VMEM scratch, dequantizes in-register (`_kv_quant`/`_kv_dequant` algebra:
  int8 codes at the static KV scale, passthrough otherwise), and folds the
  tile into the online-softmax carries (m/l/acc in VMEM scratch — the
  `flash_attn._flash_kernel` structure: init on the first block, epilogue
  `acc / max(l, eps)` on the last). GQA is a reshape: query heads (Hk, G, dh)
  contract against the Hk kv heads of the tile.

Early bound: per-slot `pos` gates each block with `pl.when(start <= pos)` —
short slots stop READING at their last active page; only the (cheap) grid
iteration continues to max_pages, and unallocated table entries inside an
active block point at page 0 (the pool's scratch page) whose tokens the
`tok <= pos` mask discards, exactly like the gather path.

The tunable is `Tile.bkq` = pages per kv block (`bm`/`bn` are unused for
this key), registered in the shipped TuneTable under the pseudo-cell key
"paged_attn/decode/*" (kernel_bench --retune sweeps it). VMEM working set
per step = 2 * bkp * page_size * Hk * dh operand bytes + the (Hq,)+(Hq,dh)
f32 carries — `vmem_decode_tile_bytes` is the bench model.

CoW / prefix-sharing contract: identical to the gather path — the kernel
only READS through `pages`; the scheduler forks shared pages before the
decode write lands (launch/serve.py `_prepare_pages`), and this kernel runs
on the post-fork table the server passes to the decode step.

Exactness: validated against the gather path at the attention-output level
(tests/test_paged_attn.py, tight f32 tolerance — the online-softmax
block accumulation is the same algebra at a different reduction order, so
bitwise equality is not the contract there; the serving oracle suites'
token-exactness with the kernel enabled is).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .harness import Tile, fit_block

NEG_INF = -1e30

#: TuneTable pseudo-cell key for this kernel (same "w/a/impl" key shape as
#: the qgemm cells; only Tile.bkq — pages per kv block — is meaningful).
TUNE_KEY = ("paged_attn", "decode", "*")
DEFAULT_PAGES_PER_BLOCK = 4


def resolve_pages_per_block(tune=None) -> int:
    """Pages-per-kv-block from a TuneTable (the shipped one by default)."""
    if tune is None:
        from .dispatch import default_tune
        tune = default_tune()
    tile = tune.tiles.get(TUNE_KEY)
    if tile is None or tile.bkq is None:
        return DEFAULT_PAGES_PER_BLOCK
    return int(tile.bkq)


def vmem_decode_tile_bytes(page_size: int, hk: int, dh: int, hq: int,
                           bkp: int, kv_bytes: int = 1) -> int:
    """VMEM working set of one grid step (the kernel_bench tile model):
    K+V page tiles in the pool dtype, their f32 dequantized values, the q
    tile and the online-softmax carries."""
    t = bkp * page_size
    return (2 * t * hk * dh * kv_bytes      # K/V scratch tiles (pool dtype)
            + 2 * t * hk * dh * 4           # dequantized f32 operands
            + hq * dh * 4                   # q tile
            + (2 * hq + hq * dh) * 4)       # m, l, acc carries


def _paged_decode_kernel(pages_ref, pos_ref, q_ref, k_hbm, v_hbm, o_ref,
                         k_scr, v_scr, m_ref, l_ref, acc_ref, sem, *,
                         page_size, bkp, hk, scale, kv_int8, kv_scale):
    b, jb = pl.program_id(0), pl.program_id(1)
    t = bkp * page_size

    @pl.when(jb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[b]

    # early page-loop bound: a block whose first token is past the slot's
    # write position holds no valid KV — skip both the DMAs and the math
    @pl.when(jb * t <= pos)
    def _step():
        copies = []
        for i in range(bkp):
            pid = pages_ref[b, jb * bkp + i]
            copies.append(pltpu.make_async_copy(
                k_hbm.at[pid], k_scr.at[i], sem.at[0, i]))
            copies.append(pltpu.make_async_copy(
                v_hbm.at[pid], v_scr.at[i], sem.at[1, i]))
        for cp in copies:
            cp.start()
        for cp in copies:
            cp.wait()

        _, hq, dh = q_ref.shape
        g = hq // hk
        q = q_ref[0]                                   # (hq, dh)
        k = k_scr[...].reshape(t, hk, dh)
        v = v_scr[...].reshape(t, hk, dh)
        if kv_int8:
            # in-register dequant: the _kv_dequant algebra at the static scale
            k = (k.astype(jnp.float32) * kv_scale).astype(q.dtype)
            v = (v.astype(jnp.float32) * kv_scale).astype(q.dtype)
        else:
            k, v = k.astype(q.dtype), v.astype(q.dtype)

        qg = q.reshape(hk, g, dh)
        s = jnp.einsum("hgd,thd->hgt", qg, k).astype(jnp.float32) * scale
        s = s.reshape(hq, t)
        tok = jb * t + jax.lax.broadcasted_iota(jnp.int32, (hq, t), 1)
        s = jnp.where(tok <= pos, s, NEG_INF)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("hgt,thd->hgd", p.reshape(hk, g, t).astype(v.dtype), v)
        acc_new = acc_prev * corr[:, None] + pv.reshape(hq, dh).astype(jnp.float32)
        m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(jb == pl.num_programs(1) - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pages_per_block", "kv_scale",
                                             "interpret"))
def paged_flash_decode(q: jnp.ndarray, k_pool: jnp.ndarray,
                       v_pool: jnp.ndarray, pages: jnp.ndarray,
                       pos: jnp.ndarray, *, pages_per_block: int | None = None,
                       kv_scale: float = 0.05,
                       interpret: bool = True) -> jnp.ndarray:
    """Single-token decode attention through the page-table indirection.

    q: (B, Hq, dh) compute dtype; k_pool/v_pool: (num_pages, page_size, Hk,
    dh) pool dtype (int8 codes at `kv_scale`, or the compute dtype); pages:
    (B, max_pages) int32 page table (NULL/unallocated entries point at the
    scratch page 0); pos: (B,) int32 per-slot positions — the new token's
    KV must ALREADY be written at pages[b, pos[b]//P] offset pos[b]%P (the
    caller owns the write, same as the gather path). Returns (B, Hq, dh).

    `pages_per_block` (Tile.bkq of the "paged_attn/decode/*" TuneTable
    entry; clamped to a divisor of max_pages) sets how many pages one grid
    step DMAs and folds into the online-softmax carries.
    """
    b, hq, dh = q.shape
    num_pages, page_size, hk, dh_k = k_pool.shape
    assert dh == dh_k and v_pool.shape == k_pool.shape
    assert hq % hk == 0, (hq, hk)
    max_pages = pages.shape[1]
    assert pages.shape == (b, max_pages) and pos.shape == (b,)
    if pages_per_block is None:
        pages_per_block = resolve_pages_per_block()
    bkp = fit_block(pages_per_block, max_pages)
    grid = (b, max_pages // bkp)
    kv_int8 = k_pool.dtype == jnp.int8

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hq, dh), lambda bi, j, pages, pos: (bi, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),     # K pool stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),     # V pool stays in HBM
        ],
        out_specs=pl.BlockSpec((1, hq, dh), lambda bi, j, pages, pos: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bkp, page_size, hk, dh), k_pool.dtype),
            pltpu.VMEM((bkp, page_size, hk, dh), v_pool.dtype),
            pltpu.VMEM((hq,), jnp.float32),           # m: running max
            pltpu.VMEM((hq,), jnp.float32),           # l: running denominator
            pltpu.VMEM((hq, dh), jnp.float32),        # acc: running output
            pltpu.SemaphoreType.DMA((2, bkp)),
        ],
    )
    kernel = functools.partial(
        _paged_decode_kernel, page_size=page_size, bkp=bkp, hk=hk,
        scale=1.0 / dh ** 0.5, kv_int8=kv_int8, kv_scale=kv_scale)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, dh), q.dtype),
        interpret=interpret,
    )(pages.astype(jnp.int32), pos.astype(jnp.int32), q, k_pool, v_pool)
