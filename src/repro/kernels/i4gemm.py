"""int4 (s4 nibble-packed) MAC body — the W4A8 vMAC path.

Beyond-paper operating point between BrainTTA's ternary and int8 modes:
weights are s4 codes packed 8 per 32-bit word (v_C=8, `core.pack.pack_int4`),
activations are int8 codes. The step unpacks the nibble words to int8 *in
VMEM* (`pack.unpack_int4_i8` — the same decoder the jnp formulation uses, so
jnp-vs-pallas equivalence is an algebra check) and rides the int8 MXU; HBM
traffic stays nibble-packed. The requant epilogue composes the per-channel
int4 weight scale with the activation scale exactly like every other cell —
it lives once in `harness.gemm`.

Registration into the serve stack lives in `repro.kernels.dispatch`
(operating points w-int4 × a-int8 and weight-only int4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pack

from .harness import MacBody, Tile, gemm


def _w4a8_step(xs, ws, accs, *, bkq):
    k = bkq * pack.NIBBLES
    w = pack.unpack_int4_i8(ws[0], k)                       # (bn, k) s4 codes
    dot = jax.lax.dot_general(xs[0], w, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (accs[0] + dot,)


INT4_W_I8A = MacBody("i4gemm_w4a8", n_x=1, n_w=1, n_acc=1,
                     k_per_q=pack.NIBBLES, xk_per_q=1, wk_per_q=pack.NIBBLES,
                     step=_w4a8_step, finish=lambda accs, k: accs[0],
                     unpacks_i8=True, default_bkq=64)


def i4gemm(x_q: jnp.ndarray, w_q4: jnp.ndarray, w_scale: jnp.ndarray,
           a_scale: jnp.ndarray, bias: jnp.ndarray | None = None, *,
           k: int, bm: int = 128, bn: int = 128, bkw: int = 64,
           interpret: bool = True) -> jnp.ndarray:
    """(M, K)i8 × (N, K/8)u32 nibble words → (M, N) bf16, fused requant."""
    return gemm(INT4_W_I8A, (x_q,), (w_q4,), w_scale, a_scale, bias,
                k=k, tile=Tile(bm, bn, bkw), interpret=interpret)
