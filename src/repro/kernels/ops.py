"""DEPRECATED compat shim — the serve GEMM moved to `repro.kernels.dispatch`.

Everything this module used to own (activation quantize/pack, M-padding,
block-size selection, expert vmap, bias fusion) lives exactly once in
`dispatch.qgemm`, keyed by `dispatch.OperatingPoint`. Every wrapper below
emits a `DeprecationWarning` and will be removed one release after the
OperatingPoint API landed; no in-tree code calls them (CI runs the dispatch
suite with `-W error::DeprecationWarning` to keep it that way). Out-of-tree
callers: build a `QLinearSpec` and call

    qgemm(packed_params, x, spec, OperatingPoint.for_spec(spec, backend="pallas"))

NOTE the interpret knob moved with the logic: rebind
`repro.kernels.dispatch.INTERPRET` (or set REPRO_PALLAS_INTERPRET before
import). It is deliberately NOT re-exported here — a stale
`ops.INTERPRET = False` would be silently ignored, which is worse than the
AttributeError you get now.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from .dispatch import OperatingPoint, qgemm  # noqa: F401  (one-release re-export)


def _deprecated(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; {repl}",
        DeprecationWarning, stacklevel=3)


def _spec(k: int, n: int, wprec: str, aprec: str):
    from repro.core.precision import LayerQuant
    from repro.core.qlinear import QLinearSpec
    from repro.core.quantize import QuantSpec
    return QLinearSpec(k, n, LayerQuant(QuantSpec(wprec), QuantSpec(aprec)))


def binary_matmul(x: jnp.ndarray, w_packed: jnp.ndarray, w_scale: jnp.ndarray,
                  *, k: int, impl: str = "popcount") -> jnp.ndarray:
    """bf16/f32 acts -> binarize+pack -> binary GEMM. (..., K) -> (..., N)."""
    _deprecated("binary_matmul",
                "call dispatch.qgemm with OperatingPoint('binary','binary',impl,'pallas')")
    spec = _spec(k, w_packed.shape[0], "binary", "binary")
    return qgemm({"w_packed": w_packed, "w_scale": w_scale}, x, spec,
                 OperatingPoint.for_spec(spec, impl=impl, backend="pallas"))


def ternary_matmul(x: jnp.ndarray, w_mask: jnp.ndarray, w_sign: jnp.ndarray,
                   w_scale: jnp.ndarray, *, k: int,
                   impl: str = "popcount") -> jnp.ndarray:
    _deprecated("ternary_matmul",
                "call dispatch.qgemm with OperatingPoint('ternary','ternary',impl,'pallas')")
    spec = _spec(k, w_mask.shape[0], "ternary", "ternary")
    return qgemm({"w_mask": w_mask, "w_sign": w_sign, "w_scale": w_scale}, x,
                 spec, OperatingPoint.for_spec(spec, impl=impl, backend="pallas"))


def int8_matmul(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                a_scale_const: jnp.ndarray,
                bias: jnp.ndarray | None = None) -> jnp.ndarray:
    _deprecated("int8_matmul",
                "call dispatch.qgemm with OperatingPoint('int8','int8','*','pallas')")
    p = {"w_q": w_q, "w_scale": w_scale, "a_scale": a_scale_const}
    if bias is not None:
        p["b"] = bias
    spec = _spec(x.shape[-1], w_q.shape[1], "int8", "int8")
    return qgemm(p, x, spec, OperatingPoint.for_spec(spec, backend="pallas"))


def qlinear_serve(p: dict, x: jnp.ndarray, spec, *,
                  impl: str = "popcount") -> jnp.ndarray:
    """Old Pallas-backend entry of `core.qlinear.apply` — now one line."""
    _deprecated("qlinear_serve",
                "call dispatch.qgemm(p, x, spec, OperatingPoint.for_spec(spec, backend='pallas'))")
    return qgemm(p, x, spec,
                 OperatingPoint.for_spec(spec, impl=impl, backend="pallas"))
