"""Compat shim — the model-facing serve GEMM moved to `repro.kernels.dispatch`.

Everything this module used to own (activation quantize/pack, M-padding,
block-size selection, expert vmap, bias fusion) now lives exactly once in
`dispatch.qgemm`. The wrappers below keep the old entry points alive for
out-of-tree callers; new code should import `qgemm` directly.

NOTE the interpret knob moved with the logic: rebind
`repro.kernels.dispatch.INTERPRET` (or set REPRO_PALLAS_INTERPRET before
import). It is deliberately NOT re-exported here — a stale
`ops.INTERPRET = False` would be silently ignored, which is worse than the
AttributeError you get now.
"""
from __future__ import annotations

import jax.numpy as jnp

from .dispatch import qgemm


def _spec(k: int, n: int, wprec: str, aprec: str):
    from repro.core.precision import LayerQuant
    from repro.core.qlinear import QLinearSpec
    from repro.core.quantize import QuantSpec
    return QLinearSpec(k, n, LayerQuant(QuantSpec(wprec), QuantSpec(aprec)))


def binary_matmul(x: jnp.ndarray, w_packed: jnp.ndarray, w_scale: jnp.ndarray,
                  *, k: int, impl: str = "popcount") -> jnp.ndarray:
    """bf16/f32 acts -> binarize+pack -> binary GEMM. (..., K) -> (..., N)."""
    return qgemm({"w_packed": w_packed, "w_scale": w_scale}, x,
                 _spec(k, w_packed.shape[0], "binary", "binary"),
                 impl=impl, backend="pallas")


def ternary_matmul(x: jnp.ndarray, w_mask: jnp.ndarray, w_sign: jnp.ndarray,
                   w_scale: jnp.ndarray, *, k: int,
                   impl: str = "popcount") -> jnp.ndarray:
    return qgemm({"w_mask": w_mask, "w_sign": w_sign, "w_scale": w_scale}, x,
                 _spec(k, w_mask.shape[0], "ternary", "ternary"),
                 impl=impl, backend="pallas")


def int8_matmul(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                a_scale_const: jnp.ndarray,
                bias: jnp.ndarray | None = None) -> jnp.ndarray:
    p = {"w_q": w_q, "w_scale": w_scale, "a_scale": a_scale_const}
    if bias is not None:
        p["b"] = bias
    return qgemm(p, x, _spec(x.shape[-1], w_q.shape[1], "int8", "int8"),
                 backend="pallas")


def qlinear_serve(p: dict, x: jnp.ndarray, spec, *,
                  impl: str = "popcount") -> jnp.ndarray:
    """Old Pallas-backend entry of `core.qlinear.apply` — now one line."""
    return qgemm(p, x, spec, impl=impl, backend="pallas")
