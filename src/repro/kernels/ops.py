"""jit'd wrappers: reshape/pad model-shaped tensors into kernel-shaped GEMMs.

`qlinear_serve` is the entry point `repro.core.qlinear.apply(backend="pallas")`
dispatches to. It quantizes+packs the activations, flattens leading dims to M,
pads M up to the sublane multiple, calls the Pallas kernel, and unpads.

On this CPU container kernels run with interpret=True (set
REPRO_PALLAS_INTERPRET=0 on real TPU).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import pack
from repro.core.quantize import int8_codes, ternarize

from . import bgemm as _bgemm
from . import i8gemm as _i8gemm
from . import tgemm as _tgemm

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def _pad_rows(x: jnp.ndarray, mult: int) -> tuple[jnp.ndarray, int]:
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, m


def _block_m(m: int) -> int:
    for bm in (128, 64, 32, 16, 8):
        if m % bm == 0:
            return bm
    return m


def binary_matmul(x: jnp.ndarray, w_packed: jnp.ndarray, w_scale: jnp.ndarray,
                  *, k: int, impl: str = "popcount") -> jnp.ndarray:
    """bf16/f32 acts -> binarize+pack -> bgemm. x: (..., K) -> (..., N)."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, k).astype(jnp.float32)
    a_scale = jnp.mean(jnp.abs(xf), axis=-1)                       # XNOR-Net alpha
    xp = pack.pack_binary(jnp.where(xf >= 0, 1.0, -1.0))
    xp, m = _pad_rows(xp, 8)
    a_scale = jnp.pad(a_scale, (0, xp.shape[0] - m))
    y = _bgemm.bgemm(xp, w_packed, w_scale, a_scale, k=k,
                     bm=_block_m(xp.shape[0]), impl=impl, interpret=INTERPRET)
    return y[:m].reshape(*lead, -1)


def ternary_matmul(x: jnp.ndarray, w_mask: jnp.ndarray, w_sign: jnp.ndarray,
                   w_scale: jnp.ndarray, *, k: int) -> jnp.ndarray:
    lead = x.shape[:-1]
    xf = x.reshape(-1, k).astype(jnp.float32)
    a_scale = jnp.mean(jnp.abs(xf), axis=-1)
    xm, xs = pack.pack_ternary(jax.lax.stop_gradient(ternarize(xf)))
    xm, m = _pad_rows(xm, 8)
    xs, _ = _pad_rows(xs, 8)
    a_scale = jnp.pad(a_scale, (0, xm.shape[0] - m))
    y = _tgemm.tgemm(xm, xs, w_mask, w_sign, w_scale, a_scale, k=k,
                     bm=_block_m(xm.shape[0]), interpret=INTERPRET)
    return y[:m].reshape(*lead, -1)


def int8_matmul(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                a_scale_const: jnp.ndarray, bias: jnp.ndarray | None = None) -> jnp.ndarray:
    lead = x.shape[:-1]
    k = x.shape[-1]
    xq = int8_codes(x.reshape(-1, k).astype(jnp.float32), a_scale_const)
    xq, m = _pad_rows(xq, 8)
    a_scale = jnp.full((xq.shape[0],), a_scale_const, jnp.float32)
    y = _i8gemm.i8gemm(xq, w_q, w_scale, a_scale, bias,
                       bm=_block_m(xq.shape[0]), interpret=INTERPRET)
    return y[:m].reshape(*lead, -1)


def qlinear_serve(p: dict, x: jnp.ndarray, spec, *, impl: str = "popcount") -> jnp.ndarray:
    """Pallas backend for repro.core.qlinear.apply(mode='serve').

    The packed kernels implement the W&A-quantized GEMMs (both operands
    narrow — the paper's operating points). Weight-only policies keep bf16
    activations, so they take the same MXU formulation as the jnp backend
    (quantizing acts here would silently change the algebra vs QAT — caught
    by the jnp-vs-pallas serve equivalence check)."""
    if spec.experts:
        import dataclasses
        sub = dataclasses.replace(spec, experts=0)
        return jax.vmap(lambda pp, xx: qlinear_serve(pp, xx, sub, impl=impl))(
            {k: v for k, v in p.items()}, x)
    wprec = spec.lq.weights.precision
    aprec = spec.lq.acts.precision
    k = spec.in_dim
    if wprec == "binary" and aprec == "binary":
        y = binary_matmul(x, p["w_packed"], p["w_scale"], k=k, impl=impl)
    elif wprec == "ternary" and aprec == "ternary":
        y = ternary_matmul(x, p["w_mask"], p["w_sign"], p["w_scale"], k=k)
    elif wprec == "int8" and aprec == "int8":
        a_s = p.get("a_scale", jnp.float32(0.05))
        y = int8_matmul(x, p["w_q"], p["w_scale"], a_s)
    else:
        # weight-only / dense: identical formulation to the jnp backend
        from repro.core.qlinear import _apply_serve_jnp
        return _apply_serve_jnp(p, x, spec, impl)
    if "b" in p and wprec != "int8":
        y = (y.astype(jnp.float32) + p["b"]).astype(jnp.bfloat16)
    return y
