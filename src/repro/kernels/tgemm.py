"""Ternary gated-XNOR MAC bodies — the vTMAC unit.

Trits are stored as two bit-planes (mask, sign) per `repro.core.pack`:
16 trits per 32-bit word-pair (v_C=16, §IV-B). The gated-XNOR algebra
(§II-A): a lane contributes only when both operands are non-zero
(mask AND), the product sign is the XOR of the sign bits:

    active   = xm & wm
    disagree = active & (xs ^ ws)
    dot     += popcount(active) − 2·popcount(disagree)

TERNARY_POPCOUNT keeps two int32 accumulators (active, disagree) and
resolves the dot in finish(); TERNARY_MXU is the beyond-paper variant that
unpacks the trit planes to {-1,0,+1} in VMEM and rides the MXU. Both share
`harness.gemm`'s output-stationary skeleton and fused requant epilogue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pack

from .harness import MacBody, Tile, gemm

WORD = 32


def _popcount_step(xs, ws, accs, *, bkq):
    xm, xsg = xs                            # (bm, bkq) mask/sign planes
    wm, wsg = ws                            # (bn, bkq)

    def body(i, carry):
        act, dis = carry
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)
        xmi, xsi = sl(xm), sl(xsg)                    # (bm, 1)
        wmi, wsi = sl(wm).T, sl(wsg).T                # (1, bn)
        active = jnp.bitwise_and(xmi, wmi)            # (bm, bn)
        disagree = jnp.bitwise_and(active, jnp.bitwise_xor(xsi, wsi))
        act = act + jax.lax.population_count(active).astype(jnp.int32)
        dis = dis + jax.lax.population_count(disagree).astype(jnp.int32)
        return act, dis

    return jax.lax.fori_loop(0, bkq, body, (accs[0], accs[1]))


def _popcount_finish(accs, k_total):
    return accs[0] - 2 * accs[1]            # dot = active - 2*disagree


TERNARY_POPCOUNT = MacBody("tgemm_popcount", n_x=2, n_w=2, n_acc=2,
                           k_per_q=WORD, step=_popcount_step,
                           finish=_popcount_finish)


def _mxu_step(xs, ws, accs, *, bkq):
    k = bkq * WORD
    xf = pack.unpack_ternary_i8(xs[0], xs[1], k).astype(jnp.float32)  # (bm, k)
    wf = pack.unpack_ternary_i8(ws[0], ws[1], k).astype(jnp.float32)  # (bn, k)
    dot = jax.lax.dot_general(xf, wf, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return (accs[0] + dot.astype(jnp.int32),)


TERNARY_MXU = MacBody("tgemm_mxu", n_x=2, n_w=2, n_acc=1, k_per_q=WORD,
                      step=_mxu_step, finish=lambda accs, k: accs[0],
                      unpacks_f32=True)


def _wt_i8a_step(xs, ws, accs, *, bkq):
    """Mixed w-ternary × a-int8: trit weight planes unpack to {-1,0,+1} int8
    in VMEM and ride the int8 MXU against the activation codes. The two
    operand sides have different storage densities — x is (bm, bkq*32) int8
    codes, w is two (bn, bkq) word planes — which is exactly what the
    harness's per-side xk_per_q/wk_per_q blocking exists for."""
    k = bkq * WORD
    w = pack.unpack_ternary_i8(ws[0], ws[1], k)             # (bn, k) trits
    dot = jax.lax.dot_general(xs[0], w, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (accs[0] + dot,)


TERNARY_W_I8A = MacBody("tgemm_wt_i8a", n_x=1, n_w=2, n_acc=1, k_per_q=WORD,
                        xk_per_q=1, wk_per_q=WORD, step=_wt_i8a_step,
                        finish=lambda accs, k: accs[0], unpacks_i8=True,
                        default_bkq=8)


def tgemm(x_mask, x_sign, w_mask, w_sign, w_scale, a_scale, *, k: int,
          bm: int = 128, bn: int = 128, bkw: int = 16,
          impl: str = "popcount", interpret: bool = True) -> jnp.ndarray:
    """Packed ternary GEMM: planes (M, K/32)u32 × (N, K/32)u32 → (M, N) bf16."""
    body = TERNARY_POPCOUNT if impl == "popcount" else TERNARY_MXU
    return gemm(body, (x_mask, x_sign), (w_mask, w_sign), w_scale, a_scale,
                k=k, tile=Tile(bm, bn, bkw), interpret=interpret)
