"""Ternary gated-XNOR+popcount GEMM — the vTMAC unit as a Pallas TPU kernel.

Trits are stored as two bit-planes (mask, sign) per `repro.core.pack`:
16 trits per 32-bit word-pair (v_C=16, §IV-B). The gated-XNOR algebra
(§II-A): a lane contributes only when both operands are non-zero
(mask AND), the product sign is the XOR of the sign bits:

    active   = xm & wm
    disagree = active & (xs ^ ws)
    dot     += popcount(active) − 2·popcount(disagree)

Same output-stationary skeleton and fused requant epilogue as bgemm; two
int32 VMEM accumulators (active count, disagree count).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD = 32


def _tgemm_kernel(xm_ref, xs_ref, wm_ref, ws_ref, wsc_ref, asc_ref,
                  o_ref, act_ref, dis_ref, *, bkw):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        act_ref[...] = jnp.zeros_like(act_ref)
        dis_ref[...] = jnp.zeros_like(dis_ref)

    xm, xs = xm_ref[...], xs_ref[...]   # (bm, bkw)
    wm, ws = wm_ref[...], ws_ref[...]   # (bn, bkw)

    def body(i, carry):
        act, dis = carry
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)
        xmi, xsi = sl(xm), sl(xs)                     # (bm, 1)
        wmi, wsi = sl(wm).T, sl(ws).T                 # (1, bn)
        active = jnp.bitwise_and(xmi, wmi)            # (bm, bn)
        disagree = jnp.bitwise_and(active, jnp.bitwise_xor(xsi, wsi))
        act = act + jax.lax.population_count(active).astype(jnp.int32)
        dis = dis + jax.lax.population_count(disagree).astype(jnp.int32)
        return act, dis

    act, dis = jax.lax.fori_loop(0, bkw, body, (act_ref[...], dis_ref[...]))
    act_ref[...], dis_ref[...] = act, dis

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        dot = act_ref[...] - 2 * dis_ref[...]
        y = dot.astype(jnp.float32) * wsc_ref[...][None, :] * asc_ref[...][:, None]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "bkw", "interpret"))
def tgemm(x_mask, x_sign, w_mask, w_sign, w_scale, a_scale, *, k: int,
          bm: int = 128, bn: int = 128, bkw: int = 16,
          interpret: bool = True) -> jnp.ndarray:
    """Packed ternary GEMM: planes (M, K/32)u32 × (N, K/32)u32 → (M, N) bf16."""
    m, kw = x_mask.shape
    n, kw2 = w_mask.shape
    assert kw == kw2 and kw * WORD == k
    bm, bn, bkw = min(bm, m), min(bn, n), min(bkw, kw)
    assert m % bm == 0 and n % bn == 0 and kw % bkw == 0

    grid = (m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        functools.partial(_tgemm_kernel, bkw=bkw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bkw), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bkw), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bkw), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32), pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_mask, x_sign, w_mask, w_sign, w_scale, a_scale)
