"""Generic output-stationary tiled GEMM harness — one Pallas skeleton, many
precisions.

BrainTTA's point is a single flexible datapath that serves binary, ternary and
int8 operands through the same machine (§III). The TPU translation of that is
this module: ONE pallas_call scaffold — grid (M/bm, N/bn, Kq/bkq) with K
innermost, int32 accumulator tiles held in VMEM scratch across the K sweep,
and the requantization epilogue (w_scale[n] * a_scale[m] + bias[n], §IV-B
"as early as possible") fused behind the MAC on the last K step — and a
`MacBody` per precision that supplies ONLY the inner MAC computation
(xnor-popcount, gated-xnor, int8-dot, mxu-unpack).

`repro.kernels.{bgemm,tgemm,i8gemm}` shrink to MacBody definitions; the
precision registry in `repro.kernels.dispatch` maps (wprec, aprec, impl)
operating points onto bodies. Adding a kernel variant = one MacBody + one
registry entry; the grid/BlockSpec/scratch/epilogue machinery below is never
copied again.

Kq is the *storage* K axis: K/32 packed words for the bit-plane formats
(body.k_per_q = 32), K int8 codes for the 8-bit format (body.k_per_q = 1),
K/8 nibble words for s4. Mixed w/a precisions give the two operand sides
different densities (xk_per_q / wk_per_q); the grid quantum is their lcm so
every K step covers whole storage units of both. Block shapes are a `Tile`
(bm, bn, bkq) — the unit the per-cell `dispatch.TuneTable` tunes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True)
class Tile:
    """One kernel block-shape choice: the tunable of an operating point.

    bm/bn block the output tile; bkq blocks the K sweep in units of the
    body's grid quantum `k_per_q` (packed words for the bit-plane formats,
    elements for int8). None bkq = the body's default. Carried on
    `dispatch.OperatingPoint` (explicit override) or resolved from a
    `dispatch.TuneTable` (per-cell autotune data)."""
    bm: int = 128
    bn: int = 128
    bkq: int | None = None


@dataclasses.dataclass(frozen=True)
class MacBody:
    """The per-precision inner MAC of the output-stationary kernel.

    step(xs, ws, accs, *, bkq) -> new accs
        one grid K-step update, bkq in grid-quantum units. xs: n_x
        activation tiles (bm, bkq*k_per_q/xk_per_q);
        ws: n_w weight tiles ((bn, bkq*k_per_q/wk_per_q) or transposed per
        w_kmajor); accs: n_acc int32 (bm, bn) accumulator values.
    finish(accs, k_total) -> (bm, bn) int32/f32 dot
        maps the raw accumulators to the integer dot product (e.g. the
        XNOR identity K - 2*mismatches) right before requantization.

    Activation and weight operands may use DIFFERENT storage densities
    (mixed w/a precision, e.g. ternary planes × int8 codes): xk_per_q /
    wk_per_q give each side's K elements per storage unit (None =>
    k_per_q). k_per_q is the grid quantum — the lcm of the two sides — so
    one K grid step always covers whole storage units of both operands.
    """
    name: str
    n_x: int                 # activation operand arrays, each (M, Kq_x)
    n_w: int                 # weight operand arrays
    n_acc: int               # int32 VMEM accumulator tiles
    k_per_q: int             # K elements per grid-K unit (coarsest operand)
    step: Callable
    finish: Callable
    w_kmajor: bool = False   # True: weights are (Kq, N) (int8 codes layout)
    unpacks_f32: bool = False  # step materializes f32 (R, bkq*k_per_q)
                               # unpacked operand tiles in VMEM (MXU bodies)
    unpacks_i8: bool = False   # step materializes int8 unpacked weight tiles
    default_bkq: int = 16
    xk_per_q: int | None = None  # activation storage density (None = k_per_q)
    wk_per_q: int | None = None  # weight storage density (None = k_per_q)
    w_stack: int = 0         # >0: weight operands carry a leading stacked
                             # plane axis — (planes, N, Kq) — swept whole per
                             # grid step (plane-composed cells). The value is
                             # the FULL stack depth (the vmem model's worst
                             # case); the live depth is the operand's shape[0]
                             # (a truncated stack just traces a smaller tile).

    @property
    def xk(self) -> int:
        return self.xk_per_q or self.k_per_q

    @property
    def wk(self) -> int:
        return self.wk_per_q or self.k_per_q


def requant(dot, w_scale, a_scale, bias):
    """The fused requant epilogue, defined once for every backend.

    out = dot * w_scale[n] * a_scale[m] + bias[n], computed in f32 so the
    wide accumulator never round-trips through a narrow dtype (§IV-B). Any
    scale/bias may be None (identity). Callers cast the result themselves.
    """
    y = dot.astype(jnp.float32)
    if w_scale is not None:
        y = y * w_scale[None, :]
    if a_scale is not None:
        y = y * a_scale[:, None]
    if bias is not None:
        y = y + bias[None, :]
    return y


def _kernel(*refs, body: MacBody, k_total: int, bkq: int, acc_only: bool):
    """One (bm, bn) output tile; grid dim 2 sweeps Kq (output-stationary)."""
    nx, nw = body.n_x, body.n_w
    x_tiles = tuple(refs[i][...] for i in range(nx))
    w_tiles = tuple(refs[nx + i][...] for i in range(nw))
    ws_ref, as_ref, b_ref = refs[nx + nw:nx + nw + 3]
    o_ref = refs[nx + nw + 3]
    acc_refs = refs[nx + nw + 4:]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        for a in acc_refs:
            a[...] = jnp.zeros_like(a)

    new_accs = body.step(x_tiles, w_tiles,
                         tuple(a[...] for a in acc_refs), bkq=bkq)
    for a, v in zip(acc_refs, new_accs):
        a[...] = v

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        dot = body.finish(tuple(a[...] for a in acc_refs), k_total)
        if acc_only:
            # tensor-parallel row shard: emit the raw integer dot so the
            # caller can psum partial sums across K shards BEFORE requant
            # (requantizing per-shard partials is numerically wrong)
            o_ref[...] = dot.astype(jnp.int32)
        else:
            y = requant(dot, ws_ref[...], as_ref[...], b_ref[...])
            o_ref[...] = y.astype(o_ref.dtype)


def fit_block(requested: int, dim: int, align: int = 1) -> int:
    """Largest block <= requested that divides dim exactly, preferring
    multiples of `align` (TPU sublane alignment for the M block — an
    unaligned int32 accumulator tile won't compile outside interpret mode).
    Falls back to a plain divisor when no aligned one exists."""
    top = max(min(requested, dim), 1)
    for b in range(top, 0, -1):
        if dim % b == 0 and b % align == 0:
            return b
    for b in range(top, 0, -1):
        if dim % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=(
    "body", "k", "tile", "interpret", "out"))
def gemm(body: MacBody, x_ops: Sequence[jnp.ndarray], w_ops: Sequence[jnp.ndarray],
         w_scale: jnp.ndarray, a_scale: jnp.ndarray,
         bias: jnp.ndarray | None = None, *, k: int,
         tile: Tile | None = None,
         interpret: bool = True, out: str = "requant") -> jnp.ndarray:
    """Run `body` through the shared output-stationary skeleton.

    x_ops: n_x arrays (M, K/xk_per_q); w_ops: n_w arrays (N, K/wk_per_q)
    ((Kq, N) if w_kmajor); w_scale (N,) f32; a_scale (M,) f32; bias (N,) f32
    or None (fused in the epilogue — no separate f32 round-trip)
    -> (M, N) bf16.

    `tile` is the block-shape choice (a `Tile`; None = the body's default).
    `dispatch.qgemm` resolves it from the OperatingPoint's explicit override
    or the per-cell TuneTable before calling here.

    out="acc" skips the requant epilogue and returns the raw (M, N) int32
    dot instead — the row-parallel tensor-parallel path runs the kernel per
    K shard and psums the integer partials across the model axis before the
    (deferred, out-of-kernel) requant. w_scale/a_scale/bias may then be None.

    Block sizes are clamped to the largest divisor of each dim; callers
    (`dispatch.qgemm`) handle M padding. interpret=True on CPU (validation),
    False on real TPU.
    """
    if out not in ("requant", "acc"):
        raise ValueError(f"out={out!r}")
    tile = tile or Tile()
    q, xk, wk = body.k_per_q, body.xk, body.wk
    assert q % xk == 0 and q % wk == 0, (body.name, q, xk, wk)
    m = x_ops[0].shape[0]
    if body.w_stack:
        n = w_ops[0].shape[-2]
    else:
        n = w_ops[0].shape[0] if not body.w_kmajor else w_ops[0].shape[1]
    units = k // q                  # grid-quantum count along K
    assert units * q == k, (body.name, k, q)
    for xo in x_ops:
        assert xo.shape == (m, k // xk), (xo.shape, m, k, xk)
    for wo in w_ops:
        if body.w_stack:
            assert wo.ndim == 3 and wo.shape[-2:] == (n, k // wk) \
                and 1 <= wo.shape[0] <= body.w_stack, (wo.shape, n, k, wk)
        else:
            assert wo.shape == ((n, k // wk) if not body.w_kmajor
                                else (k // wk, n)), (wo.shape, n, k, wk)
    bm = fit_block(tile.bm, m, align=8)
    bn = fit_block(tile.bn, n)
    bkq = fit_block(tile.bkq if tile.bkq is not None else body.default_bkq,
                    units)
    bx, bw = bkq * q // xk, bkq * q // wk   # per-side block widths (units)
    if out == "acc":
        # scales are unused by the raw-accumulator epilogue; feed dummies so
        # the BlockSpecs stay uniform. In requant mode None scales stay a
        # loud error — substituting zeros would silently zero the output.
        w_scale = jnp.zeros((n,), jnp.float32) if w_scale is None else w_scale
        a_scale = jnp.zeros((m,), jnp.float32) if a_scale is None else a_scale
    if bias is None:
        bias = jnp.zeros((n,), jnp.float32)

    x_spec = pl.BlockSpec((bm, bx), lambda i, j, kk: (i, kk))
    if body.w_stack:
        # stacked plane axis rides whole in every grid step (the plane loop
        # lives inside the MacBody); live depth is the operand's, so a
        # truncated draft stack traces a proportionally smaller tile
        stack = w_ops[0].shape[0]
        w_spec = pl.BlockSpec((stack, bn, bw), lambda i, j, kk: (0, j, kk))
    elif body.w_kmajor:
        w_spec = pl.BlockSpec((bw, bn), lambda i, j, kk: (kk, j))
    else:
        w_spec = pl.BlockSpec((bn, bw), lambda i, j, kk: (j, kk))
    grid = (m // bm, n // bn, units // bkq)
    out_dtype = jnp.int32 if out == "acc" else jnp.bfloat16
    return pl.pallas_call(
        functools.partial(_kernel, body=body, k_total=k, bkq=bkq,
                          acc_only=(out == "acc")),
        grid=grid,
        in_specs=(
            [x_spec] * body.n_x + [w_spec] * body.n_w + [
                pl.BlockSpec((bn,), lambda i, j, kk: (j,)),   # w_scale
                pl.BlockSpec((bm,), lambda i, j, kk: (i,)),   # a_scale
                pl.BlockSpec((bn,), lambda i, j, kk: (j,)),   # bias
            ]),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)] * body.n_acc,
        interpret=interpret,
    )(*x_ops, *w_ops, w_scale, a_scale, bias)


def gemm_grouped(body: MacBody, x_ops, w_ops, w_scale=None, a_scale=None,
                 bias=None, *, k: int, tile: Tile | None = None,
                 interpret: bool = True, out: str = "requant"):
    """Grouped-expert entry point: `gemm` vmapped over a leading group axis.

    Every operand (each x_op, each w_op, and any non-None scale/bias)
    carries the same leading G axis; one Pallas launch runs per group
    member on its own token slab — the segment-GEMM of the expert-parallel
    MoE path (kernels.dispatch._ep_row). None operands stay None (they map
    to `gemm`'s zero dummies), so the (M, N) algebra per group is exactly
    `gemm`'s — grouped-vs-looped equivalence is an identity, not a check.
    """
    ops = {"x": tuple(x_ops), "w": tuple(w_ops)}
    if w_scale is not None:
        ops["ws"] = w_scale
    if a_scale is not None:
        ops["as"] = a_scale
    if bias is not None:
        ops["b"] = bias
    fn = lambda d: gemm(body, d["x"], d["w"], d.get("ws"), d.get("as"),
                        d.get("b"), k=k, tile=tile, interpret=interpret,
                        out=out)
    return jax.vmap(fn)(ops)


def vmem_tile_bytes(body: MacBody, tile: Tile | None = None) -> int:
    """VMEM working set of one grid step (the kernel_bench tile model)."""
    tile = tile or Tile()
    bm, bn = tile.bm, tile.bn
    bkq = tile.bkq if tile.bkq is not None else body.default_bkq
    q = body.k_per_q
    bx, bw = bkq * q // body.xk, bkq * q // body.wk  # per-side storage units
    xb = 4 if body.xk > 1 else 1                     # u32 words vs int8 codes
    wb = 4 if body.wk > 1 else 1
    k_elems = bkq * q
    unpacked = ((body.n_x * bm + body.n_w * bn) * k_elems * 4
                if body.unpacks_f32 else 0)          # f32 ±1/trit operands
    if body.unpacks_i8:
        unpacked += body.n_w * bn * k_elems          # int8 unpacked weights
    stack = body.w_stack or 1                        # full-depth worst case
    return (body.n_x * bm * bx * xb                  # activation tiles
            + body.n_w * bn * bw * wb * stack        # weight tiles (x planes)
            + unpacked                               # MXU-body intermediates
            + body.n_acc * bm * bn * 4               # int32 accumulators
            + bm * bn * 2                            # bf16 out tile
            + (bm + 2 * bn) * 4)                     # scales + bias
