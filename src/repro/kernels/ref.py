"""Pure-jnp oracles for the Pallas GEMM kernels.

These define the exact semantics each kernel must reproduce; the kernel tests
sweep shapes/dtypes and assert_allclose against these. All three GEMMs share
the BrainTTA contract (DESIGN.md §6):

  out[m, n] = requant( sum_k x[m, k] * w[n, k] )   with the fused epilogue
  requant(acc) = acc * w_scale[n] * a_scale[m]  (+ bias[n])        -> bf16

Operand encodings match `repro.core.pack`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pack


def binary_gemm_ref(x_packed: jnp.ndarray, w_packed: jnp.ndarray, k: int,
                    w_scale: jnp.ndarray, a_scale: jnp.ndarray) -> jnp.ndarray:
    """XNOR-popcount GEMM oracle.

    x_packed: (M, K/32) uint32, w_packed: (N, K/32) uint32,
    w_scale: (N,) f32, a_scale: (M,) f32 -> (M, N) bf16.
    """
    x = pack.unpack_binary(x_packed, k)          # (M, K) in {-1,+1}
    w = pack.unpack_binary(w_packed, k)          # (N, K)
    acc = x @ w.T                                # exact in f32 (values ±K)
    return (acc * w_scale[None, :] * a_scale[:, None]).astype(jnp.bfloat16)


def ternary_gemm_ref(x_mask, x_sign, w_mask, w_sign, k: int,
                     w_scale, a_scale) -> jnp.ndarray:
    """Gated-XNOR popcount GEMM oracle (trit planes)."""
    x = pack.unpack_ternary(x_mask, x_sign, k)   # (M, K) in {-1,0,+1}
    w = pack.unpack_ternary(w_mask, w_sign, k)   # (N, K)
    acc = x @ w.T
    return (acc * w_scale[None, :] * a_scale[:, None]).astype(jnp.bfloat16)


def i8_gemm_ref(x_q: jnp.ndarray, w_q: jnp.ndarray,
                w_scale: jnp.ndarray, a_scale: jnp.ndarray,
                bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """int8 GEMM oracle with fused requant epilogue.

    x_q: (M, K) int8, w_q: (K, N) int8, w_scale: (N,), a_scale: (M,) -> bf16.
    """
    acc = jax.lax.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    y = acc.astype(jnp.float32) * w_scale[None, :] * a_scale[:, None]
    if bias is not None:
        y = y + bias[None, :]
    return y.astype(jnp.bfloat16)


def binary_gemm_mxu_ref(x_packed, w_packed, k: int, w_scale, a_scale) -> jnp.ndarray:
    """Oracle for the beyond-paper MXU formulation — semantics identical to
    binary_gemm_ref (the formulations must agree bit-exactly on the int acc)."""
    return binary_gemm_ref(x_packed, w_packed, k, w_scale, a_scale)


def wt_i8a_gemm_ref(x_q, w_mask, w_sign, k: int, w_scale, a_scale,
                    bias=None) -> jnp.ndarray:
    """Mixed w-ternary × a-int8 oracle: int8 codes against unpacked trits.

    x_q: (M, K) int8, trit planes (N, K/32) uint32 -> (M, N) bf16. The
    requant composes the ternary per-channel alpha with the int8 activation
    scale — no matched-precision assumption.
    """
    w = pack.unpack_ternary(w_mask, w_sign, k)   # (N, K) in {-1,0,+1}
    acc = x_q.astype(jnp.float32) @ w.T          # exact: small ints in f32
    y = acc * w_scale[None, :] * a_scale[:, None]
    if bias is not None:
        y = y + bias[None, :]
    return y.astype(jnp.bfloat16)


def i4_gemm_ref(x_q, w_q4, k: int, w_scale, a_scale, bias=None) -> jnp.ndarray:
    """int4-weight (s4 nibble words) × int8-activation oracle.

    x_q: (M, K) int8, w_q4: (N, K/8) uint32 -> (M, N) bf16.
    """
    w = pack.unpack_int4_i8(w_q4, k).astype(jnp.float32)   # (N, K) in [-7,7]
    acc = x_q.astype(jnp.float32) @ w.T
    y = acc * w_scale[None, :] * a_scale[:, None]
    if bias is not None:
        y = y + bias[None, :]
    return y.astype(jnp.bfloat16)
