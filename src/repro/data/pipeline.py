"""Data pipeline: deterministic, shardable, restartable token streams.

Sources:
  * SyntheticLM — seeded Zipfian token stream (self-contained; what the
    examples/benchmarks train on).
  * PackedFileSource — memory-mapped uint16/uint32 token files (the
    production path: tokenize offline, mmap here).

The pipeline is *step-indexed*: `batch_at(step)` is a pure function of
(seed, step), so a restarted job resumes the exact stream position from the
checkpointed step — no iterator state to persist (fault-tolerance substrate).
Per-host sharding: each host materializes only its slice of the global batch
(`host_slice`), which feeds jax.make_array_from_process_local_data on real
multi-host pods; on this container host_count=1.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.2          # synthetic source skew


class SyntheticLM:
    """Deterministic Zipfian LM stream with a repeated-ngram structure so a
    model can actually learn (loss decreases measurably within ~100 steps)."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # fixed "motifs" reused across the stream: learnable structure
        self.motifs = base.integers(
            0, cfg.vocab, size=(64, 16)).astype(np.int32)

    def host_slice(self) -> tuple[int, int]:
        per = self.cfg.global_batch // self.cfg.host_count
        return self.cfg.host_index * per, per

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        start, per = self.host_slice()
        rng = np.random.default_rng((cfg.seed, step))
        t = cfg.seq_len + 1
        n_mot = t // 16 + 1
        idx = rng.integers(0, len(self.motifs), size=(per, n_mot))
        stream = self.motifs[idx].reshape(per, -1)[:, :t]
        # sprinkle Zipf noise at 20% positions
        noise_mask = rng.random((per, t)) < 0.2
        noise = (rng.zipf(cfg.zipf_a, size=(per, t)) - 1) % cfg.vocab
        stream = np.where(noise_mask, noise.astype(np.int32), stream)
        return {"tokens": stream[:, :-1], "targets": stream[:, 1:]}


class PackedFileSource:
    """mmap'd token file -> fixed-length rows; step-indexed like SyntheticLM."""

    def __init__(self, cfg: PipelineConfig, path: str, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.rows = (len(self.data) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        per = cfg.global_batch // cfg.host_count
        rng = np.random.default_rng((cfg.seed, step))
        rows = rng.integers(0, self.rows, size=(per,))
        offs = rows * cfg.seq_len
        t = cfg.seq_len
        toks = np.stack([self.data[o:o + t + 1] for o in offs]).astype(np.int32)
        return {"tokens": toks[:, :-1] % cfg.vocab,
                "targets": toks[:, 1:] % cfg.vocab}


def make_source(cfg: PipelineConfig, path: str | None = None):
    return PackedFileSource(cfg, path) if path else SyntheticLM(cfg)


class Prefetcher:
    """One-batch-ahead prefetch on a worker thread (overlap host data prep
    with device compute — the data-pipeline half of comm/compute overlap)."""

    def __init__(self, source, start_step: int = 0):
        import queue
        import threading
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=2)
        self._stop = threading.Event()

        def work():
            step = start_step
            while not self._stop.is_set():
                try:
                    self.q.put((step, source.batch_at(step)), timeout=0.5)
                    step += 1
                except Exception:
                    continue
        self.thread = threading.Thread(target=work, daemon=True)
        self.thread.start()

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
