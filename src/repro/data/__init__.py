"""Deterministic, shardable, restartable data pipelines."""
from . import pipeline  # noqa: F401
from .pipeline import PipelineConfig, SyntheticLM, make_source, Prefetcher  # noqa: F401
