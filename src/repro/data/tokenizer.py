"""Byte-level tokenizer: the smallest real tokenizer that exercises the
serving path end to end (EOS retirement, prompt encoding, decode printing).

Token space: ids 0..255 are raw bytes, then BOS=256, EOS=257, PAD=258 —
259 ids total, which fits every `reduced()` config (vocab=512) as well as
any production vocab. No merges, no training, no external files: encode is
UTF-8 bytes, decode is the inverse (specials stripped), and round-tripping
is exact for arbitrary text.

This is deliberately NOT a BPE: the serving layer only needs a stable
text <-> ids bijection plus a real EOS id to retire on
(launch/serve.Request.eos). Swapping in a learned tokenizer later changes
nothing in the server.
"""
from __future__ import annotations

import numpy as np


class ByteTokenizer:
    BOS = 256
    EOS = 257
    PAD = 258
    vocab_size = 259

    def __init__(self, vocab: int | None = None):
        """`vocab`: optional model vocab to validate against (must hold all
        259 ids; reduced configs use 512)."""
        if vocab is not None and vocab < self.vocab_size:
            raise ValueError(f"model vocab {vocab} cannot hold the "
                             f"{self.vocab_size}-id byte tokenizer")

    def encode(self, text: str, *, bos: bool = True,
               eos: bool = False) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if bos:
            ids.insert(0, self.BOS)
        if eos:
            ids.append(self.EOS)
        return np.asarray(ids, dtype=np.int32)

    def decode(self, ids) -> str:
        by = bytes(int(i) for i in np.asarray(ids).ravel()
                   if 0 <= int(i) < 256)
        return by.decode("utf-8", errors="replace")
