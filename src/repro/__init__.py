"""repro — BrainTTA (mixed-precision b/t/i8 quantized NN compute) as a
production-grade multi-pod JAX training/inference framework."""
