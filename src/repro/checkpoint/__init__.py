"""Sharded atomic checkpointing with elastic (mesh-shape-changing) restore."""
from . import ckpt  # noqa: F401
from .ckpt import save, restore, latest_step  # noqa: F401
