"""Sharded, atomic, mesh-elastic checkpointing.

Layout:  <dir>/step_<N>/
             manifest.json     tree structure, shapes, dtypes, step, mesh info
             shard_<i>.npz     leaf arrays (flat index -> array)
         <dir>/LATEST          text file naming the newest complete step

Properties needed at 1000+ nodes, implemented here at laptop scale:
  * atomicity — written to step_<N>.tmp, fsync'd, renamed; a crash mid-write
    never corrupts LATEST (restart ignores .tmp).
  * retention — keep_n newest checkpoints, older ones pruned after a
    successful write (never before).
  * resume — `latest_step(dir)` + `restore(dir, like=tree)`; the train driver
    resumes data position from the step (step-indexed pipeline).
  * elasticity — arrays are saved *unsharded by logical leaf* with the mesh
    shape recorded; restore re-shards onto whatever mesh the new job has
    (tested 8 -> 4 devices in tests/test_elastic.py). At real scale each host
    would write its shard; the manifest/rename protocol is identical.
  * int8 optimizer states and packed uint32 weights round-trip unchanged.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

MANIFEST = "manifest.json"
LATEST = "LATEST"


def _paths(tree):
    leaves, tdef = jax.tree.flatten(tree)
    return leaves, tdef


def save(ckpt_dir: str, step: int, tree, *, mesh_shape=None, keep_n: int = 3,
         extra: dict | None = None) -> str:
    """Atomically write `tree` as checkpoint `step`. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, tdef = _paths(tree)
    arrays = [np.asarray(x) for x in leaves]
    np.savez(os.path.join(tmp, "shard_0.npz"),
             **{f"a{i}": a for i, a in enumerate(arrays)})
    manifest = {
        "step": step,
        "n_leaves": len(arrays),
        "treedef": str(tdef),
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [str(a.dtype) for a in arrays],
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):      # re-save of the same step (post-resume)
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, LATEST + ".tmp"), "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.rename(os.path.join(ckpt_dir, LATEST + ".tmp"),
              os.path.join(ckpt_dir, LATEST))
    _prune(ckpt_dir, keep_n)
    return final


def _prune(ckpt_dir: str, keep_n: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_n]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    path = os.path.join(ckpt_dir, LATEST)
    if not os.path.exists(path):
        return None
    name = open(path).read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, MANIFEST)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like, *, step: int | None = None, shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). If `shardings` (matching pytree of NamedSharding) is
    given, leaves are placed onto the new mesh — elastic restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))
    arrays = [data[f"a{i}"] for i in range(manifest["n_leaves"])]
    leaves, tdef = _paths(like)
    if len(leaves) != len(arrays):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, model needs "
                         f"{len(leaves)} — architecture mismatch")
    out = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(arrays))
    for a, l, s in zip(arrays, leaves, shard_leaves):
        if tuple(a.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch {a.shape} vs {l.shape}")
        if s is not None:
            out.append(jax.device_put(a, s))
        else:
            out.append(jax.numpy.asarray(a, dtype=l.dtype))
    return tdef.unflatten(out), manifest


def manifest_extra(ckpt_dir: str, step: int | None = None) -> dict:
    if step is None:
        step = latest_step(ckpt_dir)
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        return json.load(f).get("extra", {})
